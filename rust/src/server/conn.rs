//! Per-connection reader/writer.
//!
//! Each accepted connection gets one thread running [`handle_conn`].
//! The read side alternates between a short *poll* timeout on the
//! first header byte (so the thread notices shutdown while idle) and a
//! hard per-frame deadline once a frame has started — a client that
//! sends half a header and stalls holds the thread for at most
//! `frame_deadline`, then is dropped as a slow client. Mid-frame
//! disconnects and malformed bytes never propagate past this module:
//! the connection is answered (best-effort) with a structured error
//! frame and closed, and the listener thread keeps accepting.

use std::io::{ErrorKind, Read};
use std::net::{Shutdown, SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::RecvTimeoutError;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::exec::faults;

use super::protocol::{
    parse_frame_header, parse_incoming, write_response, ErrorCode, Incoming, ProtoError, Response,
    HEADER_LEN,
};
use super::scheduler::{Counters, SchedulerHandle};

/// How often an idle connection re-checks the shutdown flag.
const POLL_INTERVAL: Duration = Duration::from_millis(100);

/// Per-connection limits, copied out of the server config.
#[derive(Clone, Copy, Debug)]
pub struct ConnConfig {
    /// Once a frame's first byte arrives, the rest of the frame must
    /// arrive within this long or the client is dropped as slow.
    pub frame_deadline: Duration,
    /// Socket write timeout for response frames.
    pub write_timeout: Duration,
    /// How long a connection waits for the engine's reply before
    /// answering `TIMEOUT`.
    pub request_timeout: Duration,
}

/// Why the read side stopped mid-connection.
enum ReadStop {
    /// Peer closed the socket (clean or mid-frame).
    Disconnected,
    /// Frame started but did not complete within the deadline.
    SlowClient,
    /// Bytes violated the protocol; framing is lost.
    Proto(ProtoError),
    /// Transport error.
    Io,
}

enum ReadOutcome {
    /// Poll tick expired with no bytes — re-check shutdown and retry.
    Idle,
    /// One complete frame body, plus the read-stage span (first byte to
    /// full frame) in nanoseconds for the `gconv_read_ns` histogram.
    Frame(Vec<u8>, u64),
}

/// Read exactly `buf.len()` bytes with an absolute deadline, using the
/// socket read timeout to bound each blocking read.
fn read_exact_deadline(
    stream: &mut TcpStream,
    buf: &mut [u8],
    deadline: Instant,
) -> Result<(), ReadStop> {
    let mut off = 0;
    while off < buf.len() {
        let now = Instant::now();
        if now >= deadline {
            return Err(ReadStop::SlowClient);
        }
        stream.set_read_timeout(Some(deadline - now)).map_err(|_| ReadStop::Io)?;
        match stream.read(&mut buf[off..]) {
            Ok(0) => return Err(ReadStop::Disconnected),
            Ok(n) => off += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                return Err(ReadStop::SlowClient);
            }
            Err(_) => return Err(ReadStop::Io),
        }
    }
    Ok(())
}

/// Wait up to one poll tick for the next frame; once its first byte
/// arrives, read the whole frame under the per-frame deadline.
fn poll_frame(stream: &mut TcpStream, cfg: &ConnConfig) -> Result<ReadOutcome, ReadStop> {
    stream.set_read_timeout(Some(POLL_INTERVAL)).map_err(|_| ReadStop::Io)?;
    let mut header = [0u8; HEADER_LEN];
    match stream.read(&mut header[..1]) {
        Ok(0) => return Err(ReadStop::Disconnected),
        Ok(_) => {}
        Err(e) if e.kind() == ErrorKind::Interrupted => return Ok(ReadOutcome::Idle),
        Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
            return Ok(ReadOutcome::Idle);
        }
        Err(_) => return Err(ReadStop::Io),
    }
    // The read span starts at the first byte so idle poll ticks never
    // pollute the histogram.
    let span = crate::obs::Span::start();
    let deadline = Instant::now() + cfg.frame_deadline;
    read_exact_deadline(stream, &mut header[1..], deadline)?;
    let body_len = parse_frame_header(&header).map_err(ReadStop::Proto)?;
    let mut body = vec![0u8; body_len as usize];
    read_exact_deadline(stream, &mut body, deadline)?;
    Ok(ReadOutcome::Frame(body, span.elapsed_ns()))
}

fn send_error(stream: &mut TcpStream, code: ErrorCode, message: String) -> bool {
    let resp = Response::Error { code, message };
    write_response(stream, &resp).is_ok()
}

/// Serve one connection until the peer disconnects, a fatal read error
/// occurs, or the server shuts down.
pub fn handle_conn(
    mut stream: TcpStream,
    peer: SocketAddr,
    sched: SchedulerHandle,
    cfg: ConnConfig,
    shutdown: Arc<AtomicBool>,
    counters: Arc<Counters>,
) {
    let _ = peer; // retained for thread naming by the caller
    let _ = stream.set_nodelay(true);
    let _ = stream.set_write_timeout(Some(cfg.write_timeout));
    loop {
        if shutdown.load(Ordering::SeqCst) {
            let _ = send_error(
                &mut stream,
                ErrorCode::ShuttingDown,
                "server is shutting down".into(),
            );
            break;
        }
        let body = match poll_frame(&mut stream, &cfg) {
            Ok(ReadOutcome::Idle) => continue,
            Ok(ReadOutcome::Frame(body, read_ns)) => {
                counters.read_ns.record(read_ns);
                body
            }
            Err(ReadStop::Disconnected) => break,
            Err(ReadStop::SlowClient) => {
                counters.slow_clients.inc();
                let _ = send_error(
                    &mut stream,
                    ErrorCode::Timeout,
                    "frame not completed within the read deadline".into(),
                );
                break;
            }
            Err(ReadStop::Proto(e)) => {
                // Framing is unrecoverable after a bad header: answer
                // once, then close.
                counters.malformed.inc();
                let _ = send_error(&mut stream, e.code, e.msg);
                break;
            }
            Err(ReadStop::Io) => break,
        };
        // Fault site `conn.read`: fires once per complete frame. An
        // injected `Err` degrades exactly like a transport fault — a
        // structured INTERNAL answer on a still-framed connection.
        if let Err(e) = faults::trip(faults::SITE_CONN_READ) {
            if send_error(&mut stream, ErrorCode::Internal, e.to_string()) {
                continue;
            }
            break;
        }
        // A complete-but-invalid body keeps its framing, so the
        // connection stays usable after the error response.
        let request = match parse_incoming(&body) {
            Ok(Incoming::Request(req)) => req,
            Ok(Incoming::Health) => {
                if write_response(&mut stream, &Response::Health(sched.health())).is_err() {
                    break;
                }
                continue;
            }
            Ok(Incoming::Metrics) => {
                // Answered inline like health frames: metrics requests
                // never enter the queue and never consume a request
                // budget slot.
                let text = counters.metrics_text();
                if write_response(&mut stream, &Response::Metrics(text)).is_err() {
                    break;
                }
                continue;
            }
            Err(e) => {
                counters.malformed.inc();
                if send_error(&mut stream, e.code, e.msg) {
                    continue;
                }
                break;
            }
        };
        let response = match sched.submit(&request.model, request.data) {
            Err((code, message)) => Response::Error { code, message },
            Ok(reply) => match reply.recv_timeout(cfg.request_timeout) {
                Ok(Ok(data)) => Response::Output { dims: vec![data.len()], data },
                Ok(Err((code, message))) => Response::Error { code, message },
                Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => {
                    counters.timeouts.inc();
                    Response::Error {
                        code: ErrorCode::Timeout,
                        message: "request timed out waiting for the engine".into(),
                    }
                }
            },
        };
        let write_span = crate::obs::Span::start();
        if write_response(&mut stream, &response).is_err() {
            break;
        }
        counters.write_ns.record(write_span.elapsed_ns());
    }
    let _ = stream.shutdown(Shutdown::Both);
}
