//! Versioned length-prefixed binary frame protocol of the TCP serving
//! front.
//!
//! Every frame is `MAGIC (4 bytes) ++ body_len (u32 LE) ++ body`, and
//! every body starts with `version (u16 LE) ++ kind (u8)`. The seven
//! kinds:
//!
//! | kind | body after the common prefix |
//! | --- | --- |
//! | request (1) | `name_len: u16`, `name: UTF-8`, `batch: u16` (must be 1 in v1), `ndims: u8`, `dims: ndims × u32`, `payload: ∏dims × f32` |
//! | output (2) | `ndims: u8`, `dims: ndims × u32`, `payload: ∏dims × f32` |
//! | error (3) | `code: u16` (see [`ErrorCode`]), `msg_len: u16`, `msg: UTF-8` |
//! | health request (4) | *(empty)* |
//! | health (5) | 14 × `u64` counters in [`HealthSnapshot`] field order (the one [`HEALTH_FIELDS`] table drives both codec directions), `nq: u16`, `nq` × (`strikes: u32`, `name_len: u16`, `name: UTF-8`) |
//! | metrics request (6) | *(empty)* |
//! | metrics (7) | `text_len: u32`, `text: UTF-8` — a Prometheus-style exposition, capped at [`MAX_METRICS_TEXT`] |
//!
//! All integers and floats are little-endian. The hard caps
//! ([`MAX_BODY_BYTES`], [`MAX_NAME_LEN`], [`MAX_DIMS`],
//! [`MAX_ERROR_MSG`]) are enforced *before* any allocation sized by a
//! wire field, so a malformed or hostile header can never trigger a
//! huge allocation: a reader refuses the frame at the 8-byte prefix.
//! Parsing is total — every violation maps to a structured
//! [`ProtoError`] carrying the [`ErrorCode`] the server sends back.

use std::fmt;
use std::io::{Read, Write};

/// Leading bytes of every frame (`b"GCS1"` — GCONV chain serve, v1
/// framing).
pub const MAGIC: [u8; 4] = *b"GCS1";
/// Protocol version carried in every frame body.
pub const VERSION: u16 = 1;
/// Bytes of the fixed frame prefix: magic + body length.
pub const HEADER_LEN: usize = 8;
/// Hard cap on a frame body (64 MiB). A `body_len` above this is
/// refused before any buffer is allocated.
pub const MAX_BODY_BYTES: u32 = 1 << 26;
/// Hard cap on the model-name field.
pub const MAX_NAME_LEN: usize = 64;
/// Hard cap on the tensor rank a request or response may carry.
pub const MAX_DIMS: usize = 8;
/// Error messages are truncated to this many bytes on the wire.
pub const MAX_ERROR_MSG: usize = 256;
/// Hard cap on the quarantine entries a health frame carries (encoders
/// truncate, parsers refuse above it).
pub const MAX_QUARANTINE: usize = 64;
/// Hard cap on the metrics-frame exposition text (encoders truncate at
/// a line boundary, parsers refuse above it).
pub const MAX_METRICS_TEXT: usize = 1 << 16;

const KIND_REQUEST: u8 = 1;
const KIND_OUTPUT: u8 = 2;
const KIND_ERROR: u8 = 3;
const KIND_HEALTH_REQ: u8 = 4;
const KIND_HEALTH: u8 = 5;
const KIND_METRICS_REQ: u8 = 6;
const KIND_METRICS: u8 = 7;

/// Structured error codes of the error-response frame. The numeric
/// wire value is stable protocol surface; names are for humans.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorCode {
    /// The frame violated the protocol (bad magic, bad version, field
    /// inconsistency). The server closes the connection when framing
    /// itself is lost (bad magic), and keeps it otherwise.
    Malformed = 1,
    /// A length field exceeded its hard cap.
    TooLarge = 2,
    /// The request named a model the engine does not serve.
    UnknownModel = 3,
    /// The payload element count does not match the model's input.
    BadShape = 4,
    /// Backpressure: the submission queue or the per-model in-flight
    /// cap is full. Retry later; nothing was enqueued.
    Busy = 5,
    /// The server is draining and accepts no new work.
    ShuttingDown = 6,
    /// The engine failed internally while serving the request.
    Internal = 7,
    /// A deadline expired (mid-frame read, the reply wait, or the
    /// driver-side request deadline).
    Timeout = 8,
    /// The model is quarantined after panicking inside the driver;
    /// other models keep serving. Submits are refused until the server
    /// restarts.
    Quarantined = 9,
}

impl ErrorCode {
    /// The on-wire `u16` value.
    pub fn wire(self) -> u16 {
        self as u16
    }

    /// Decode a wire value.
    pub fn from_wire(v: u16) -> Option<ErrorCode> {
        match v {
            1 => Some(ErrorCode::Malformed),
            2 => Some(ErrorCode::TooLarge),
            3 => Some(ErrorCode::UnknownModel),
            4 => Some(ErrorCode::BadShape),
            5 => Some(ErrorCode::Busy),
            6 => Some(ErrorCode::ShuttingDown),
            7 => Some(ErrorCode::Internal),
            8 => Some(ErrorCode::Timeout),
            9 => Some(ErrorCode::Quarantined),
            _ => None,
        }
    }

    /// Stable upper-case name (`BUSY`, `BAD_SHAPE`, …).
    pub fn name(self) -> &'static str {
        match self {
            ErrorCode::Malformed => "MALFORMED",
            ErrorCode::TooLarge => "TOO_LARGE",
            ErrorCode::UnknownModel => "UNKNOWN_MODEL",
            ErrorCode::BadShape => "BAD_SHAPE",
            ErrorCode::Busy => "BUSY",
            ErrorCode::ShuttingDown => "SHUTTING_DOWN",
            ErrorCode::Internal => "INTERNAL",
            ErrorCode::Timeout => "TIMEOUT",
            ErrorCode::Quarantined => "QUARANTINED",
        }
    }
}

impl fmt::Display for ErrorCode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// A protocol violation: the [`ErrorCode`] the server reports plus a
/// human-readable detail message.
#[derive(Clone, Debug)]
pub struct ProtoError {
    /// Structured code (always `Malformed` or `TooLarge` for parse
    /// failures).
    pub code: ErrorCode,
    /// Detail for logs and error frames.
    pub msg: String,
}

impl ProtoError {
    fn malformed(msg: impl Into<String>) -> ProtoError {
        ProtoError { code: ErrorCode::Malformed, msg: msg.into() }
    }

    fn too_large(msg: impl Into<String>) -> ProtoError {
        ProtoError { code: ErrorCode::TooLarge, msg: msg.into() }
    }
}

impl fmt::Display for ProtoError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.code, self.msg)
    }
}

impl std::error::Error for ProtoError {}

/// Failure of a blocking frame read/write: either the transport broke
/// or the peer violated the protocol.
#[derive(Debug)]
pub enum FrameError {
    /// The underlying socket/stream failed (includes timeouts and
    /// mid-frame EOF as `UnexpectedEof`).
    Io(std::io::Error),
    /// The bytes arrived but violated the protocol.
    Proto(ProtoError),
}

impl fmt::Display for FrameError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FrameError::Io(e) => write!(f, "transport error: {e}"),
            FrameError::Proto(p) => write!(f, "protocol error: {p}"),
        }
    }
}

impl std::error::Error for FrameError {}

impl From<std::io::Error> for FrameError {
    fn from(e: std::io::Error) -> FrameError {
        FrameError::Io(e)
    }
}

impl From<ProtoError> for FrameError {
    fn from(e: ProtoError) -> FrameError {
        FrameError::Proto(e)
    }
}

/// A decoded inference request: model name, per-sample extents, and
/// the flattened `f32` payload (`data.len() == dims.iter().product()`,
/// enforced at parse).
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    /// Model the request targets (benchmark code, registered builder,
    /// or registered spec name).
    pub model: String,
    /// Extents of the sample tensor (batch is a separate header field,
    /// fixed to 1 in protocol v1).
    pub dims: Vec<usize>,
    /// Row-major payload.
    pub data: Vec<f32>,
}

/// One quarantined model in a [`HealthSnapshot`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct QuarantinedModel {
    /// The model code refused at admission.
    pub model: String,
    /// Driver panics attributed to the model.
    pub strikes: u32,
}

/// The body of a health frame: a point-in-time copy of the server's
/// counters plus the quarantine list. Field order is the wire order.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct HealthSnapshot {
    /// Jobs accepted into the queue.
    pub submitted: u64,
    /// Jobs answered with an output frame.
    pub completed: u64,
    /// Submissions rejected with `BUSY`.
    pub rejected_busy: u64,
    /// Jobs answered with a non-`BUSY` error frame.
    pub errored: u64,
    /// Requests whose reply wait exceeded the request timeout.
    pub timeouts: u64,
    /// Jobs whose driver-side deadline expired before evaluation.
    pub expired: u64,
    /// Submissions refused because the model is quarantined.
    pub quarantine_rejected: u64,
    /// Frames refused as malformed/oversized.
    pub malformed: u64,
    /// Connections dropped for blowing a mid-frame read deadline.
    pub slow_clients: u64,
    /// Connections accepted.
    pub conns_accepted: u64,
    /// Connections refused at the connection cap.
    pub conns_rejected: u64,
    /// Driver panics caught by the supervisor.
    pub panics: u64,
    /// Current queue depth.
    pub queue_depth: u64,
    /// High-water mark of the queue depth.
    pub max_queue_depth: u64,
    /// Models currently refused at admission (truncated to
    /// [`MAX_QUARANTINE`] on the wire).
    pub quarantined: Vec<QuarantinedModel>,
}

/// One row of [`HEALTH_FIELDS`]: the field's stable name plus shared
/// read/write accessors.
pub struct HealthField {
    /// Stable field name — also the suffix of the `gconv_*` metric the
    /// obs registry mirrors the counter under.
    pub name: &'static str,
    /// Read the field out of a snapshot.
    pub get: fn(&HealthSnapshot) -> u64,
    /// Mutable slot of the field in a snapshot (decode side).
    pub slot: fn(&mut HealthSnapshot) -> &mut u64,
}

/// The single field-order table both codec directions (and every other
/// field-by-field consumer: `stats` printing, the registry pinning
/// test) iterate. Wire order **is** this table's order — reordering a
/// row changes the protocol in one place instead of silently
/// corrupting every counter after a hand-matched line.
pub const HEALTH_FIELDS: [HealthField; 14] = [
    HealthField { name: "submitted", get: |h| h.submitted, slot: |h| &mut h.submitted },
    HealthField { name: "completed", get: |h| h.completed, slot: |h| &mut h.completed },
    HealthField {
        name: "rejected_busy",
        get: |h| h.rejected_busy,
        slot: |h| &mut h.rejected_busy,
    },
    HealthField { name: "errored", get: |h| h.errored, slot: |h| &mut h.errored },
    HealthField { name: "timeouts", get: |h| h.timeouts, slot: |h| &mut h.timeouts },
    HealthField { name: "expired", get: |h| h.expired, slot: |h| &mut h.expired },
    HealthField {
        name: "quarantine_rejected",
        get: |h| h.quarantine_rejected,
        slot: |h| &mut h.quarantine_rejected,
    },
    HealthField { name: "malformed", get: |h| h.malformed, slot: |h| &mut h.malformed },
    HealthField {
        name: "slow_clients",
        get: |h| h.slow_clients,
        slot: |h| &mut h.slow_clients,
    },
    HealthField {
        name: "conns_accepted",
        get: |h| h.conns_accepted,
        slot: |h| &mut h.conns_accepted,
    },
    HealthField {
        name: "conns_rejected",
        get: |h| h.conns_rejected,
        slot: |h| &mut h.conns_rejected,
    },
    HealthField { name: "panics", get: |h| h.panics, slot: |h| &mut h.panics },
    HealthField { name: "queue_depth", get: |h| h.queue_depth, slot: |h| &mut h.queue_depth },
    HealthField {
        name: "max_queue_depth",
        get: |h| h.max_queue_depth,
        slot: |h| &mut h.max_queue_depth,
    },
];

/// A decoded response frame.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    /// The inference output (dims is `[elements]` — the engine returns
    /// flat per-sample outputs).
    Output {
        /// Extents of the returned tensor.
        dims: Vec<usize>,
        /// Row-major payload.
        data: Vec<f32>,
    },
    /// A structured failure; the connection stays open unless framing
    /// itself was lost.
    Error {
        /// What failed.
        code: ErrorCode,
        /// Detail (truncated to [`MAX_ERROR_MSG`] on the wire).
        message: String,
    },
    /// Counters + quarantine snapshot answering a health request.
    Health(HealthSnapshot),
    /// Prometheus-style text exposition answering a metrics request
    /// (truncated to [`MAX_METRICS_TEXT`] on the wire).
    Metrics(String),
}

/// A decoded client-to-server frame (see [`parse_incoming`]).
#[derive(Clone, Debug, PartialEq)]
pub enum Incoming {
    /// An inference request.
    Request(Request),
    /// A health probe: answer with [`Response::Health`], never through
    /// the scheduler queue.
    Health,
    /// A metrics probe: answer with [`Response::Metrics`], never
    /// through the scheduler queue (and never against the request
    /// budget).
    Metrics,
}

// ---------------------------------------------------------------- read

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8], ProtoError> {
        let end = self.pos.checked_add(n).ok_or_else(|| ProtoError::malformed("length overflow"))?;
        if end > self.buf.len() {
            return Err(ProtoError::malformed(format!(
                "truncated body: {what} needs {n} bytes at offset {}, body has {}",
                self.pos,
                self.buf.len()
            )));
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8, ProtoError> {
        Ok(self.take(1, what)?[0])
    }

    fn u16(&mut self, what: &str) -> Result<u16, ProtoError> {
        let b = self.take(2, what)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self, what: &str) -> Result<u32, ProtoError> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self, what: &str) -> Result<u64, ProtoError> {
        let b = self.take(8, what)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn f32s(&mut self, n: usize, what: &str) -> Result<Vec<f32>, ProtoError> {
        let bytes = n
            .checked_mul(4)
            .ok_or_else(|| ProtoError::too_large(format!("{what}: element count overflows")))?;
        let b = self.take(bytes, what)?;
        Ok(b.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }

    fn done(&self, what: &str) -> Result<(), ProtoError> {
        if self.pos != self.buf.len() {
            return Err(ProtoError::malformed(format!(
                "{what}: {} trailing bytes after the declared fields",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

fn check_version(r: &mut Reader<'_>) -> Result<(), ProtoError> {
    let v = r.u16("version")?;
    if v != VERSION {
        return Err(ProtoError::malformed(format!(
            "unsupported protocol version {v} (this server speaks {VERSION})"
        )));
    }
    Ok(())
}

fn parse_dims(r: &mut Reader<'_>) -> Result<(Vec<usize>, usize), ProtoError> {
    let ndims = r.u8("ndims")? as usize;
    if ndims == 0 || ndims > MAX_DIMS {
        return Err(ProtoError::malformed(format!(
            "tensor rank {ndims} outside 1..={MAX_DIMS}"
        )));
    }
    let mut dims = Vec::with_capacity(ndims);
    let mut elems: usize = 1;
    for i in 0..ndims {
        let d = r.u32(&format!("dim {i}"))? as usize;
        if d == 0 {
            return Err(ProtoError::malformed(format!("dim {i} is zero")));
        }
        elems = elems
            .checked_mul(d)
            .filter(|&e| e <= MAX_BODY_BYTES as usize / 4)
            .ok_or_else(|| {
                ProtoError::too_large(format!(
                    "payload of shape {dims:?}×{d} exceeds the {MAX_BODY_BYTES}-byte frame cap"
                ))
            })?;
        dims.push(d);
    }
    Ok((dims, elems))
}

/// Validate an 8-byte frame prefix, returning the body length.
pub fn parse_frame_header(header: &[u8; HEADER_LEN]) -> Result<u32, ProtoError> {
    if header[..4] != MAGIC {
        return Err(ProtoError::malformed(format!(
            "bad frame magic {:02x?} (expected {:02x?})",
            &header[..4],
            MAGIC
        )));
    }
    let len = u32::from_le_bytes([header[4], header[5], header[6], header[7]]);
    if len > MAX_BODY_BYTES {
        return Err(ProtoError::too_large(format!(
            "frame body of {len} bytes exceeds the {MAX_BODY_BYTES}-byte cap"
        )));
    }
    if len < 3 {
        return Err(ProtoError::malformed(format!(
            "frame body of {len} bytes is shorter than the version+kind prefix"
        )));
    }
    Ok(len)
}

/// Parse a request frame body (everything after the 8-byte prefix).
pub fn parse_request(body: &[u8]) -> Result<Request, ProtoError> {
    let mut r = Reader::new(body);
    check_version(&mut r)?;
    let kind = r.u8("kind")?;
    if kind != KIND_REQUEST {
        return Err(ProtoError::malformed(format!(
            "frame kind {kind} is not a request (expected {KIND_REQUEST})"
        )));
    }
    parse_request_fields(&mut r)
}

/// Parse any client-to-server frame body: an inference request or a
/// health probe.
pub fn parse_incoming(body: &[u8]) -> Result<Incoming, ProtoError> {
    let mut r = Reader::new(body);
    check_version(&mut r)?;
    let kind = r.u8("kind")?;
    match kind {
        KIND_REQUEST => Ok(Incoming::Request(parse_request_fields(&mut r)?)),
        KIND_HEALTH_REQ => {
            r.done("health request")?;
            Ok(Incoming::Health)
        }
        KIND_METRICS_REQ => {
            r.done("metrics request")?;
            Ok(Incoming::Metrics)
        }
        other => Err(ProtoError::malformed(format!(
            "frame kind {other} is not a request (expected {KIND_REQUEST}, {KIND_HEALTH_REQ}, \
             or {KIND_METRICS_REQ})"
        ))),
    }
}

fn parse_request_fields(r: &mut Reader<'_>) -> Result<Request, ProtoError> {
    let name_len = r.u16("name_len")? as usize;
    if name_len == 0 || name_len > MAX_NAME_LEN {
        return Err(ProtoError::too_large(format!(
            "model name of {name_len} bytes outside 1..={MAX_NAME_LEN}"
        )));
    }
    let name = r.take(name_len, "model name")?;
    let model = std::str::from_utf8(name)
        .map_err(|_| ProtoError::malformed("model name is not UTF-8"))?
        .to_string();
    let batch = r.u16("batch")?;
    if batch != 1 {
        return Err(ProtoError::malformed(format!(
            "batch {batch} unsupported: protocol v1 carries one sample per request"
        )));
    }
    let (dims, elems) = parse_dims(&mut r)?;
    let data = r.f32s(elems, "payload")?;
    r.done("request")?;
    Ok(Request { model, dims, data })
}

/// Parse a response frame body.
pub fn parse_response(body: &[u8]) -> Result<Response, ProtoError> {
    let mut r = Reader::new(body);
    check_version(&mut r)?;
    let kind = r.u8("kind")?;
    match kind {
        KIND_OUTPUT => {
            let (dims, elems) = parse_dims(&mut r)?;
            let data = r.f32s(elems, "payload")?;
            r.done("output response")?;
            Ok(Response::Output { dims, data })
        }
        KIND_ERROR => {
            let wire = r.u16("error code")?;
            let code = ErrorCode::from_wire(wire)
                .ok_or_else(|| ProtoError::malformed(format!("unknown error code {wire}")))?;
            let msg_len = r.u16("msg_len")? as usize;
            if msg_len > MAX_ERROR_MSG {
                return Err(ProtoError::too_large(format!(
                    "error message of {msg_len} bytes exceeds the {MAX_ERROR_MSG}-byte cap"
                )));
            }
            let msg = r.take(msg_len, "error message")?;
            let message = String::from_utf8_lossy(msg).into_owned();
            r.done("error response")?;
            Ok(Response::Error { code, message })
        }
        KIND_HEALTH => {
            let mut h = HealthSnapshot::default();
            for field in &HEALTH_FIELDS {
                *(field.slot)(&mut h) = r.u64(field.name)?;
            }
            let nq = r.u16("quarantine count")? as usize;
            if nq > MAX_QUARANTINE {
                return Err(ProtoError::too_large(format!(
                    "{nq} quarantine entries exceed the {MAX_QUARANTINE}-entry cap"
                )));
            }
            for i in 0..nq {
                let strikes = r.u32(&format!("quarantine {i} strikes"))?;
                let name_len = r.u16(&format!("quarantine {i} name_len"))? as usize;
                if name_len == 0 || name_len > MAX_NAME_LEN {
                    return Err(ProtoError::too_large(format!(
                        "quarantine {i} name of {name_len} bytes outside 1..={MAX_NAME_LEN}"
                    )));
                }
                let name = r.take(name_len, &format!("quarantine {i} name"))?;
                let model = std::str::from_utf8(name)
                    .map_err(|_| ProtoError::malformed("quarantined model name is not UTF-8"))?
                    .to_string();
                h.quarantined.push(QuarantinedModel { model, strikes });
            }
            r.done("health response")?;
            Ok(Response::Health(h))
        }
        KIND_METRICS => {
            let text_len = r.u32("text_len")? as usize;
            if text_len > MAX_METRICS_TEXT {
                return Err(ProtoError::too_large(format!(
                    "metrics text of {text_len} bytes exceeds the {MAX_METRICS_TEXT}-byte cap"
                )));
            }
            let text = r.take(text_len, "metrics text")?;
            let text = std::str::from_utf8(text)
                .map_err(|_| ProtoError::malformed("metrics text is not UTF-8"))?
                .to_string();
            r.done("metrics response")?;
            Ok(Response::Metrics(text))
        }
        other => Err(ProtoError::malformed(format!(
            "frame kind {other} is not a response (expected {KIND_OUTPUT}, {KIND_ERROR}, \
             {KIND_HEALTH}, or {KIND_METRICS})"
        ))),
    }
}

/// Read one frame body from a blocking reader (prefix validated, body
/// allocation bounded by [`MAX_BODY_BYTES`]).
pub fn read_frame_body(r: &mut impl Read) -> Result<Vec<u8>, FrameError> {
    let mut header = [0u8; HEADER_LEN];
    r.read_exact(&mut header)?;
    let len = parse_frame_header(&header)?;
    let mut body = vec![0u8; len as usize];
    r.read_exact(&mut body)?;
    Ok(body)
}

/// Read and parse one request frame.
pub fn read_request(r: &mut impl Read) -> Result<Request, FrameError> {
    Ok(parse_request(&read_frame_body(r)?)?)
}

/// Read and parse one response frame.
pub fn read_response(r: &mut impl Read) -> Result<Response, FrameError> {
    Ok(parse_response(&read_frame_body(r)?)?)
}

// --------------------------------------------------------------- write

fn frame(body: Vec<u8>) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN + body.len());
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&(body.len() as u32).to_le_bytes());
    out.extend_from_slice(&body);
    out
}

fn push_dims(body: &mut Vec<u8>, dims: &[usize]) -> Result<(), ProtoError> {
    if dims.is_empty() || dims.len() > MAX_DIMS {
        return Err(ProtoError::malformed(format!(
            "tensor rank {} outside 1..={MAX_DIMS}",
            dims.len()
        )));
    }
    body.push(dims.len() as u8);
    for &d in dims {
        if d == 0 || d > u32::MAX as usize {
            return Err(ProtoError::malformed(format!("dim {d} not encodable as u32")));
        }
        body.extend_from_slice(&(d as u32).to_le_bytes());
    }
    Ok(())
}

fn push_payload(body: &mut Vec<u8>, dims: &[usize], data: &[f32]) -> Result<(), ProtoError> {
    let elems: usize = dims.iter().product();
    if elems != data.len() {
        return Err(ProtoError::malformed(format!(
            "shape {dims:?} holds {elems} elements, payload has {}",
            data.len()
        )));
    }
    body.reserve(4 * data.len());
    for v in data {
        body.extend_from_slice(&v.to_le_bytes());
    }
    Ok(())
}

fn check_body_cap(body: &[u8], what: &str) -> Result<(), ProtoError> {
    if body.len() > MAX_BODY_BYTES as usize {
        return Err(ProtoError::too_large(format!(
            "{what} of {} bytes exceeds the {MAX_BODY_BYTES}-byte frame cap",
            body.len()
        )));
    }
    Ok(())
}

/// Encode a complete request frame (prefix included).
pub fn encode_request(model: &str, dims: &[usize], data: &[f32]) -> Result<Vec<u8>, ProtoError> {
    if model.is_empty() || model.len() > MAX_NAME_LEN {
        return Err(ProtoError::too_large(format!(
            "model name of {} bytes outside 1..={MAX_NAME_LEN}",
            model.len()
        )));
    }
    let mut body = Vec::with_capacity(16 + model.len() + 4 * dims.len() + 4 * data.len());
    body.extend_from_slice(&VERSION.to_le_bytes());
    body.push(KIND_REQUEST);
    body.extend_from_slice(&(model.len() as u16).to_le_bytes());
    body.extend_from_slice(model.as_bytes());
    body.extend_from_slice(&1u16.to_le_bytes()); // batch (v1: always 1)
    push_dims(&mut body, dims)?;
    push_payload(&mut body, dims, data)?;
    check_body_cap(&body, "request body")?;
    Ok(frame(body))
}

/// Encode a complete health-request frame (prefix included).
pub fn encode_health_request() -> Vec<u8> {
    let mut body = Vec::with_capacity(3);
    body.extend_from_slice(&VERSION.to_le_bytes());
    body.push(KIND_HEALTH_REQ);
    frame(body)
}

/// Encode a complete metrics-request frame (prefix included).
pub fn encode_metrics_request() -> Vec<u8> {
    let mut body = Vec::with_capacity(3);
    body.extend_from_slice(&VERSION.to_le_bytes());
    body.push(KIND_METRICS_REQ);
    frame(body)
}

/// Encode a complete response frame (prefix included). Error messages
/// are truncated to [`MAX_ERROR_MSG`] bytes (on a char boundary);
/// quarantine lists are truncated to [`MAX_QUARANTINE`] entries;
/// metrics text is truncated to [`MAX_METRICS_TEXT`] bytes at a line
/// boundary.
pub fn encode_response(resp: &Response) -> Result<Vec<u8>, ProtoError> {
    let mut body = Vec::new();
    body.extend_from_slice(&VERSION.to_le_bytes());
    match resp {
        Response::Output { dims, data } => {
            body.push(KIND_OUTPUT);
            push_dims(&mut body, dims)?;
            push_payload(&mut body, dims, data)?;
        }
        Response::Error { code, message } => {
            body.push(KIND_ERROR);
            body.extend_from_slice(&code.wire().to_le_bytes());
            let mut cut = message.len().min(MAX_ERROR_MSG);
            while cut > 0 && !message.is_char_boundary(cut) {
                cut -= 1;
            }
            let msg = &message.as_bytes()[..cut];
            body.extend_from_slice(&(msg.len() as u16).to_le_bytes());
            body.extend_from_slice(msg);
        }
        Response::Health(h) => {
            body.push(KIND_HEALTH);
            for field in &HEALTH_FIELDS {
                body.extend_from_slice(&(field.get)(h).to_le_bytes());
            }
            let entries: Vec<&QuarantinedModel> = h
                .quarantined
                .iter()
                .filter(|q| !q.model.is_empty() && q.model.len() <= MAX_NAME_LEN)
                .take(MAX_QUARANTINE)
                .collect();
            body.extend_from_slice(&(entries.len() as u16).to_le_bytes());
            for q in entries {
                body.extend_from_slice(&q.strikes.to_le_bytes());
                body.extend_from_slice(&(q.model.len() as u16).to_le_bytes());
                body.extend_from_slice(q.model.as_bytes());
            }
        }
        Response::Metrics(text) => {
            body.push(KIND_METRICS);
            let text = crate::obs::export::truncate_text(text, MAX_METRICS_TEXT);
            body.extend_from_slice(&(text.len() as u32).to_le_bytes());
            body.extend_from_slice(text.as_bytes());
        }
    }
    check_body_cap(&body, "response body")?;
    Ok(frame(body))
}

/// Encode and write one request frame.
pub fn write_request(
    w: &mut impl Write,
    model: &str,
    dims: &[usize],
    data: &[f32],
) -> Result<(), FrameError> {
    let bytes = encode_request(model, dims, data)?;
    w.write_all(&bytes)?;
    w.flush()?;
    Ok(())
}

/// Encode and write one response frame. A response too large to encode
/// (an oversized output) degrades to an `INTERNAL` error frame, so the
/// client always receives *something* well-formed.
pub fn write_response(w: &mut impl Write, resp: &Response) -> std::io::Result<()> {
    let bytes = match encode_response(resp) {
        Ok(b) => b,
        Err(e) => encode_response(&Response::Error {
            code: ErrorCode::Internal,
            message: format!("response not encodable: {e}"),
        })
        .expect("error responses are bounded"),
    };
    w.write_all(&bytes)?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrips() {
        let frame = encode_request("MN", &[3, 2], &[1.0, -2.5, 0.0, 4.0, 5.0, -0.125]).unwrap();
        assert_eq!(frame[..4], MAGIC);
        let req = read_request(&mut frame.as_slice()).unwrap();
        assert_eq!(req.model, "MN");
        assert_eq!(req.dims, vec![3, 2]);
        assert_eq!(req.data, vec![1.0, -2.5, 0.0, 4.0, 5.0, -0.125]);
    }

    #[test]
    fn responses_roundtrip() {
        let out = Response::Output { dims: vec![4], data: vec![0.5, 1.5, -2.0, 3.25] };
        let bytes = encode_response(&out).unwrap();
        assert_eq!(read_response(&mut bytes.as_slice()).unwrap(), out);

        let err = Response::Error { code: ErrorCode::Busy, message: "queue full".into() };
        let bytes = encode_response(&err).unwrap();
        assert_eq!(read_response(&mut bytes.as_slice()).unwrap(), err);
    }

    #[test]
    fn error_codes_roundtrip_and_unknown_codes_fail() {
        for code in [
            ErrorCode::Malformed,
            ErrorCode::TooLarge,
            ErrorCode::UnknownModel,
            ErrorCode::BadShape,
            ErrorCode::Busy,
            ErrorCode::ShuttingDown,
            ErrorCode::Internal,
            ErrorCode::Timeout,
            ErrorCode::Quarantined,
        ] {
            assert_eq!(ErrorCode::from_wire(code.wire()), Some(code));
        }
        assert_eq!(ErrorCode::from_wire(0), None);
        assert_eq!(ErrorCode::from_wire(999), None);
    }

    #[test]
    fn health_frames_roundtrip() {
        let probe = encode_health_request();
        assert_eq!(parse_incoming(&probe[HEADER_LEN..]).unwrap(), Incoming::Health);

        let snap = HealthSnapshot {
            submitted: 10,
            completed: 7,
            rejected_busy: 1,
            errored: 2,
            timeouts: 1,
            expired: 3,
            quarantine_rejected: 4,
            malformed: 5,
            slow_clients: 6,
            conns_accepted: 8,
            conns_rejected: 9,
            panics: 2,
            queue_depth: 0,
            max_queue_depth: 12,
            quarantined: vec![QuarantinedModel { model: "bad".into(), strikes: 3 }],
        };
        let bytes = encode_response(&Response::Health(snap.clone())).unwrap();
        assert_eq!(read_response(&mut bytes.as_slice()).unwrap(), Response::Health(snap));
    }

    /// Satellite of the shared-table refactor: random snapshots must
    /// survive encode → parse bit-for-bit. With both directions driven
    /// by [`HEALTH_FIELDS`] a reordered row still round-trips (order
    /// is defined once), and a dropped row fails here immediately.
    #[test]
    fn health_frames_roundtrip_over_random_snapshots() {
        let mut rng = crate::prop::Rng::new(0x6EA_17B);
        for round in 0..64 {
            let mut snap = HealthSnapshot::default();
            for field in &HEALTH_FIELDS {
                *(field.slot)(&mut snap) = (rng.f64() * u32::MAX as f64) as u64;
            }
            let nq = (rng.f64() * 4.0) as usize;
            snap.quarantined = (0..nq)
                .map(|i| QuarantinedModel {
                    model: format!("m{i}"),
                    strikes: (rng.f64() * 9.0) as u32 + 1,
                })
                .collect();
            let bytes = encode_response(&Response::Health(snap.clone())).unwrap();
            let parsed = read_response(&mut bytes.as_slice()).unwrap();
            assert_eq!(parsed, Response::Health(snap), "round {round} diverged");
        }
    }

    #[test]
    fn health_field_table_covers_every_counter_exactly_once() {
        // Writing distinct values through the slots must read back the
        // same values through the getters — two rows aliasing one
        // field (or a field missing from the table) breaks this.
        let mut snap = HealthSnapshot::default();
        for (i, field) in HEALTH_FIELDS.iter().enumerate() {
            *(field.slot)(&mut snap) = 100 + i as u64;
        }
        for (i, field) in HEALTH_FIELDS.iter().enumerate() {
            assert_eq!((field.get)(&snap), 100 + i as u64, "field {} aliased", field.name);
        }
    }

    #[test]
    fn metrics_frames_roundtrip() {
        let probe = encode_metrics_request();
        assert_eq!(parse_incoming(&probe[HEADER_LEN..]).unwrap(), Incoming::Metrics);

        let text = "# TYPE gconv_completed counter\ngconv_completed 6\n".to_string();
        let resp = Response::Metrics(text);
        let bytes = encode_response(&resp).unwrap();
        assert_eq!(read_response(&mut bytes.as_slice()).unwrap(), resp);
    }

    #[test]
    fn oversized_metrics_text_truncates_at_a_line_boundary() {
        let line = "gconv_metric_with_a_rather_long_name 123456789\n";
        let n = MAX_METRICS_TEXT / line.len() + 2;
        let resp = Response::Metrics(line.repeat(n));
        let bytes = encode_response(&resp).unwrap();
        match read_response(&mut bytes.as_slice()).unwrap() {
            Response::Metrics(text) => {
                assert!(text.len() <= MAX_METRICS_TEXT);
                assert!(text.ends_with('\n'), "truncation must cut at a line boundary");
                assert!(text.lines().all(|l| l == line.trim_end()));
            }
            other => panic!("expected a metrics response, got {other:?}"),
        }
        // A hand-built body claiming more than the cap is refused
        // before the text is read.
        let mut body = Vec::new();
        body.extend_from_slice(&VERSION.to_le_bytes());
        body.push(KIND_METRICS);
        body.extend_from_slice(&((MAX_METRICS_TEXT + 1) as u32).to_le_bytes());
        let err = parse_response(&body).unwrap_err();
        assert_eq!(err.code, ErrorCode::TooLarge);
    }

    #[test]
    fn incoming_dispatches_requests_and_rejects_response_kinds() {
        let req = encode_request("MN", &[2], &[1.0, 2.0]).unwrap();
        match parse_incoming(&req[HEADER_LEN..]).unwrap() {
            Incoming::Request(r) => assert_eq!(r.model, "MN"),
            other => panic!("expected a request, got {other:?}"),
        }
        // An output frame is not a valid incoming kind.
        let out = encode_response(&Response::Output { dims: vec![1], data: vec![0.5] }).unwrap();
        let err = parse_incoming(&out[HEADER_LEN..]).unwrap_err();
        assert_eq!(err.code, ErrorCode::Malformed);
    }

    #[test]
    fn oversized_quarantine_lists_truncate_on_the_wire() {
        let quarantined: Vec<QuarantinedModel> = (0..MAX_QUARANTINE + 10)
            .map(|i| QuarantinedModel { model: format!("m{i}"), strikes: 1 })
            .collect();
        let snap = HealthSnapshot { quarantined, ..HealthSnapshot::default() };
        let bytes = encode_response(&Response::Health(snap)).unwrap();
        match read_response(&mut bytes.as_slice()).unwrap() {
            Response::Health(h) => assert_eq!(h.quarantined.len(), MAX_QUARANTINE),
            other => panic!("expected a health response, got {other:?}"),
        }
    }

    #[test]
    fn bad_magic_is_malformed() {
        let mut frame = encode_request("MN", &[1], &[1.0]).unwrap();
        frame[0] = b'X';
        match read_request(&mut frame.as_slice()) {
            Err(FrameError::Proto(p)) => assert_eq!(p.code, ErrorCode::Malformed),
            other => panic!("expected a malformed error, got {other:?}"),
        }
    }

    #[test]
    fn oversized_body_len_is_refused_at_the_header() {
        let mut frame = Vec::new();
        frame.extend_from_slice(&MAGIC);
        frame.extend_from_slice(&(MAX_BODY_BYTES + 1).to_le_bytes());
        match read_request(&mut frame.as_slice()) {
            Err(FrameError::Proto(p)) => assert_eq!(p.code, ErrorCode::TooLarge),
            other => panic!("expected a too-large error, got {other:?}"),
        }
    }

    #[test]
    fn truncated_frames_are_io_errors() {
        let frame = encode_request("MN", &[2], &[1.0, 2.0]).unwrap();
        let cut = &frame[..frame.len() - 3];
        match read_request(&mut &cut[..]) {
            Err(FrameError::Io(e)) => {
                assert_eq!(e.kind(), std::io::ErrorKind::UnexpectedEof);
            }
            other => panic!("expected an io error, got {other:?}"),
        }
    }

    #[test]
    fn name_and_rank_caps_are_enforced_both_ways() {
        let long = "x".repeat(MAX_NAME_LEN + 1);
        assert!(encode_request(&long, &[1], &[0.0]).is_err());
        assert!(encode_request("", &[1], &[0.0]).is_err());
        let dims = vec![1usize; MAX_DIMS + 1];
        assert!(encode_request("m", &dims, &[0.0]).is_err());

        // A hand-built body with a name_len above the cap parses to
        // TOO_LARGE without allocating the claimed length.
        let mut body = Vec::new();
        body.extend_from_slice(&VERSION.to_le_bytes());
        body.push(KIND_REQUEST);
        body.extend_from_slice(&u16::MAX.to_le_bytes());
        let err = parse_request(&body).unwrap_err();
        assert_eq!(err.code, ErrorCode::TooLarge);
    }

    #[test]
    fn payload_shape_mismatches_are_malformed() {
        assert!(encode_request("m", &[3], &[1.0, 2.0]).is_err());
        // Declared dims larger than the carried payload.
        let good = encode_request("m", &[2], &[1.0, 2.0]).unwrap();
        let mut body = good[HEADER_LEN..].to_vec();
        // dims live after version(2)+kind(1)+name_len(2)+name(1)+batch(2)
        // at offset 8: ndims byte, then the u32 extent — bump it to 3.
        body[9] = 3;
        let err = parse_request(&body).unwrap_err();
        assert_eq!(err.code, ErrorCode::Malformed);
    }

    #[test]
    fn batch_other_than_one_is_rejected() {
        let good = encode_request("m", &[1], &[1.0]).unwrap();
        let mut body = good[HEADER_LEN..].to_vec();
        // batch u16 sits after version(2)+kind(1)+name_len(2)+name(1).
        body[6] = 2;
        let err = parse_request(&body).unwrap_err();
        assert_eq!(err.code, ErrorCode::Malformed);
        assert!(err.msg.contains("batch"), "{}", err.msg);
    }

    #[test]
    fn trailing_bytes_are_rejected() {
        let mut frame = encode_request("m", &[1], &[1.0]).unwrap();
        // Grow the body by one byte and fix up the declared length.
        frame.push(0xAB);
        let body_len = (frame.len() - HEADER_LEN) as u32;
        frame[4..8].copy_from_slice(&body_len.to_le_bytes());
        match read_request(&mut frame.as_slice()) {
            Err(FrameError::Proto(p)) => {
                assert_eq!(p.code, ErrorCode::Malformed);
                assert!(p.msg.contains("trailing"), "{}", p.msg);
            }
            other => panic!("expected a malformed error, got {other:?}"),
        }
    }

    #[test]
    fn long_error_messages_truncate_on_the_wire() {
        let long = "é".repeat(MAX_ERROR_MSG); // 2 bytes per char
        let bytes = encode_response(&Response::Error {
            code: ErrorCode::Internal,
            message: long,
        })
        .unwrap();
        match read_response(&mut bytes.as_slice()).unwrap() {
            Response::Error { code, message } => {
                assert_eq!(code, ErrorCode::Internal);
                assert!(message.len() <= MAX_ERROR_MSG);
                assert!(message.chars().all(|c| c == 'é'), "truncation split a char");
            }
            other => panic!("expected an error response, got {other:?}"),
        }
    }
}
