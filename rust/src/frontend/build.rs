//! Spec → [`Network`] construction.
//!
//! Walks the layer list in order, resolves producer names (omitted
//! `inputs` default to the previous layer, so linear chains need no
//! wiring), runs the [inference pass](super::infer) on every layer, and
//! unifies declared partial outputs — so a malformed spec yields a
//! targeted error naming the offending layer, never a panic. The
//! resulting network flows through the existing `lower_network` →
//! `ChainExec` / `Session` path unchanged.

use std::collections::HashMap;

use anyhow::{bail, ensure, Context, Result};

use super::infer::{check_layer, layer_from_spec, unify_output};
use super::spec::ModelSpec;
use crate::ir::{Dim, Layer, Network, NodeId, Shape};

/// Build the network a spec describes, at its baked-in batch size.
pub fn build_network(spec: &ModelSpec) -> Result<Network> {
    build_with_batch(spec, None)
}

/// Build the network a spec describes. With `Some(b)`, every input
/// layer's `B` extent is overridden to `b` (specs bake a default batch;
/// the serving engine relowers at the micro-batch size). Inputs without
/// a `B` dimension are left untouched.
pub fn build_with_batch(spec: &ModelSpec, batch: Option<usize>) -> Result<Network> {
    ensure!(!spec.name.is_empty(), "model spec has an empty \"name\"");
    ensure!(!spec.layers.is_empty(), "model spec {:?} has no layers", spec.name);
    if let Some(b) = batch {
        ensure!(b > 0, "model spec {:?}: batch override must be positive", spec.name);
    }
    let mut net = Network::new(&spec.name);
    let mut ids: HashMap<&str, NodeId> = HashMap::with_capacity(spec.layers.len());
    let mut prev: Option<NodeId> = None;
    let mut saw_input = false;
    for ls in &spec.layers {
        ensure!(
            !ids.contains_key(ls.name.as_str()),
            "layer {:?} is defined twice",
            ls.name
        );
        let mut layer = layer_from_spec(ls)?;
        if let Layer::Input { shape } = &mut layer {
            saw_input = true;
            if let (Some(b), true) = (batch, shape.dims().contains(&Dim::B)) {
                *shape = shape.with(Dim::B, b);
            }
        }
        let input_ids = resolve_inputs(ls, &layer, &ids, prev)?;
        let in_shapes: Vec<&Shape> =
            input_ids.iter().map(|&i| &net.node(i).output).collect();
        let out = check_layer(&ls.name, &layer, &in_shapes)?;
        unify_output(&ls.name, &out, &ls.output)?;
        let id = net.add(&ls.name, layer, &input_ids);
        ids.insert(ls.name.as_str(), id);
        prev = Some(id);
    }
    ensure!(
        saw_input,
        "model spec {:?} has no \"input\" layer (every network needs one)",
        spec.name
    );
    Ok(net)
}

/// Producer node ids for one layer: explicit names resolve against
/// already-built layers (specs are topological, so a forward or unknown
/// name is a dangling input); omitted `inputs` default to the previous
/// layer.
fn resolve_inputs(
    ls: &super::spec::LayerSpec,
    layer: &Layer,
    ids: &HashMap<&str, NodeId>,
    prev: Option<NodeId>,
) -> Result<Vec<NodeId>> {
    if matches!(layer, Layer::Input { .. }) {
        if let Some(names) = &ls.inputs {
            ensure!(names.is_empty(), "layer {:?}: input layers take no inputs", ls.name);
        }
        return Ok(Vec::new());
    }
    match &ls.inputs {
        Some(names) => {
            let mut out = Vec::with_capacity(names.len());
            for n in names {
                let id = ids.get(n.as_str()).with_context(|| {
                    format!(
                        "layer {:?}: input {n:?} does not name an earlier layer \
                         (specs are topological — producers must come first)",
                        ls.name
                    )
                })?;
                out.push(*id);
            }
            Ok(out)
        }
        None => match prev {
            Some(id) => Ok(vec![id]),
            None => bail!(
                "layer {:?}: \"inputs\" omitted but there is no previous layer to \
                 default to",
                ls.name
            ),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gconv::lower::{lower_network, Mode};

    fn spec_of(layers: &str) -> ModelSpec {
        let doc = format!(
            "{{\"format\": \"gconv-chain-model\", \"version\": 1, \"name\": \"t\", \
             \"layers\": [{layers}]}}"
        );
        ModelSpec::parse_json(&doc).unwrap()
    }

    const LINEAR: &str = r#"
        {"name": "data", "kind": "input", "shape": [["B", 2], ["C", 3], ["H", 8], ["W", 8]]},
        {"name": "conv1", "kind": "conv", "kernel": 3, "pad": 1, "output": {"C": 4}},
        {"name": "relu1", "kind": "relu"},
        {"name": "pool1", "kind": "pool", "kernel": 2},
        {"name": "fc", "kind": "fc", "out_features": 5},
        {"name": "prob", "kind": "softmax"}"#;

    #[test]
    fn linear_chain_defaults_to_previous_layer() {
        let net = build_network(&spec_of(LINEAR)).unwrap();
        assert_eq!(net.len(), 6);
        assert_eq!(net.node(2).inputs, vec![1]);
        assert_eq!(net.node(1).output.extent(Dim::C), 4, "out_channels from declared C");
        assert_eq!(net.node(3).output.extent(Dim::H), 4);
        // The spec-built network lowers through the standard path.
        let chain = lower_network(&net, Mode::Inference);
        assert!(chain.len() >= net.len() - 1);
    }

    #[test]
    fn batch_override_rewrites_input_b() {
        let net = build_with_batch(&spec_of(LINEAR), Some(7)).unwrap();
        assert_eq!(net.node(0).output.extent(Dim::B), 7);
        assert_eq!(net.node(5).output.extent(Dim::B), 7);
    }

    #[test]
    fn dangling_input_is_reported() {
        let layers = r#"
            {"name": "data", "kind": "input", "shape": [["B", 1], ["C", 2], ["H", 4], ["W", 4]]},
            {"name": "r", "kind": "relu", "inputs": ["nope"]}"#;
        let err = build_network(&spec_of(layers)).unwrap_err().to_string();
        assert!(err.contains("\"r\"") && err.contains("\"nope\""), "{err}");
    }

    #[test]
    fn duplicate_names_and_missing_input_layer_are_reported() {
        let layers = r#"
            {"name": "data", "kind": "input", "shape": [["B", 1], ["C", 2], ["H", 4], ["W", 4]]},
            {"name": "data", "kind": "relu"}"#;
        let err = build_network(&spec_of(layers)).unwrap_err().to_string();
        assert!(err.contains("defined twice"), "{err}");

        let layers = r#"{"name": "r", "kind": "relu", "inputs": []}"#;
        let err = build_network(&spec_of(layers)).unwrap_err().to_string();
        assert!(err.contains("one input"), "{err}");
    }

    #[test]
    fn branching_by_name_works() {
        let layers = r#"
            {"name": "data", "kind": "input", "shape": [["B", 1], ["C", 2], ["H", 4], ["W", 4]]},
            {"name": "a", "kind": "relu", "inputs": ["data"]},
            {"name": "b", "kind": "sigmoid", "inputs": ["data"]},
            {"name": "j", "kind": "eltwise", "inputs": ["a", "b"]},
            {"name": "cat", "kind": "concat", "inputs": ["a", "b", "j"]}"#;
        let net = build_network(&spec_of(layers)).unwrap();
        assert_eq!(net.node(4).inputs, vec![1, 2, 3]);
        assert_eq!(net.node(4).output.extent(Dim::C), 6);
    }

    #[test]
    fn shape_unification_failure_names_layer_and_dim() {
        let layers = r#"
            {"name": "data", "kind": "input", "shape": [["B", 1], ["C", 2], ["H", 4], ["W", 4]]},
            {"name": "c", "kind": "conv", "out_channels": 4, "kernel": 3, "output": {"H": 4}}"#;
        let err = build_network(&spec_of(layers)).unwrap_err().to_string();
        assert!(err.contains("\"c\"") && err.contains("H = 4") && err.contains("H = 2"), "{err}");
    }
}
