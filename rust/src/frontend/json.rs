//! Minimal JSON document model for the model-spec frontend.
//!
//! The crate's only dependencies are `anyhow` and `rayon` (no parsing
//! crates are available offline), so the frontend carries its own small
//! JSON implementation: an insertion-ordered object model, a
//! recursive-descent parser with byte-offset/line/column errors, and a
//! canonical compact writer the exporter uses (`", "` between items,
//! `": "` after keys — the same separators Python's `json.dumps`
//! defaults produce, so regenerated spec files diff cleanly).

use anyhow::{bail, Result};
use std::fmt::Write as _;

/// A JSON value. Objects preserve insertion order so serialized specs
/// are byte-stable across round trips.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (specs only use integers; see [`Json::as_i64`]).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object with insertion-ordered keys.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Member `key` of an object (`None` for other variants or a
    /// missing key).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// String payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Integer payload, if this is a number with no fractional part.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && n.abs() < 9.0e15 => Some(*n as i64),
            _ => None,
        }
    }

    /// Array payload, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// Object payload, if this is an object.
    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(pairs) => Some(pairs),
            _ => None,
        }
    }

    /// Human name of the variant (for error messages).
    pub fn kind(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "boolean",
            Json::Num(_) => "number",
            Json::Str(_) => "string",
            Json::Arr(_) => "array",
            Json::Obj(_) => "object",
        }
    }

    /// Canonical compact rendering (single line): `", "` between array
    /// items and object members, `": "` after keys, integers without a
    /// fractional part.
    pub fn write_compact(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    v.write_compact(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    write_escaped(k, out);
                    out.push_str(": ");
                    v.write_compact(out);
                }
                out.push('}');
            }
        }
    }

    /// [`Json::write_compact`] into a fresh string.
    pub fn to_compact(&self) -> String {
        let mut out = String::new();
        self.write_compact(&mut out);
        out
    }
}

/// Write `s` as a JSON string literal.
fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Nesting depth cap: recursion past this returns a parse error
/// instead of overflowing the stack (specs nest a handful of levels).
const MAX_DEPTH: usize = 128;

/// Parse a JSON document (exactly one top-level value).
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser { src: text.as_bytes(), pos: 0, depth: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos < p.src.len() {
        bail!("{}: trailing content after the JSON document", p.location());
    }
    Ok(v)
}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
    depth: usize,
}

impl Parser<'_> {
    /// `line N column M` of the current position (1-based).
    fn location(&self) -> String {
        let mut line = 1;
        let mut col = 1;
        for &b in &self.src[..self.pos.min(self.src.len())] {
            if b == b'\n' {
                line += 1;
                col = 1;
            } else {
                col += 1;
            }
        }
        format!("line {line} column {col}")
    }

    fn err(&self, msg: &str) -> anyhow::Error {
        anyhow::anyhow!("{}: {msg}", self.location())
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        if self.depth >= MAX_DEPTH {
            return Err(self.err("nesting deeper than the supported maximum"));
        }
        self.depth += 1;
        let v = self.value_inner();
        self.depth -= 1;
        v
    }

    fn value_inner(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            Some(b) => Err(self.err(&format!("unexpected character '{}'", b as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.src[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            if b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.src[start..self.pos]).expect("ascii");
        match text.parse::<f64>() {
            Ok(n) if n.is_finite() => Ok(Json::Num(n)),
            _ => Err(self.err(&format!("invalid number '{text}'"))),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let Some(b) = self.peek() else {
                return Err(self.err("unterminated string"));
            };
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => out.push(self.escape()?),
                _ => {
                    // Re-decode the UTF-8 sequence starting at this byte.
                    let start = self.pos - 1;
                    let len = utf8_len(b).ok_or_else(|| self.err("invalid UTF-8"))?;
                    let end = start + len;
                    let s = self
                        .src
                        .get(start..end)
                        .and_then(|bytes| std::str::from_utf8(bytes).ok())
                        .ok_or_else(|| self.err("invalid UTF-8"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn escape(&mut self) -> Result<char> {
        let Some(b) = self.peek() else {
            return Err(self.err("unterminated escape"));
        };
        self.pos += 1;
        Ok(match b {
            b'"' => '"',
            b'\\' => '\\',
            b'/' => '/',
            b'b' => '\u{8}',
            b'f' => '\u{c}',
            b'n' => '\n',
            b'r' => '\r',
            b't' => '\t',
            b'u' => {
                let hi = self.hex4()?;
                let code = if (0xD800..0xDC00).contains(&hi) {
                    // Surrogate pair: a low-surrogate \uXXXX must follow.
                    if self.src[self.pos..].starts_with(b"\\u") {
                        self.pos += 2;
                        let lo = self.hex4()?;
                        if !(0xDC00..0xE000).contains(&lo) {
                            return Err(self.err("invalid low surrogate in \\u pair"));
                        }
                        0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                    } else {
                        return Err(self.err("unpaired surrogate escape"));
                    }
                } else {
                    hi
                };
                char::from_u32(code).ok_or_else(|| self.err("invalid \\u escape"))?
            }
            _ => return Err(self.err(&format!("unknown escape '\\{}'", b as char))),
        })
    }

    fn hex4(&mut self) -> Result<u32> {
        let end = self.pos + 4;
        let s = self
            .src
            .get(self.pos..end)
            .and_then(|bytes| std::str::from_utf8(bytes).ok())
            .ok_or_else(|| self.err("truncated \\u escape"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("invalid \\u escape"))?;
        self.pos = end;
        Ok(v)
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut pairs: Vec<(String, Json)> = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            if pairs.iter().any(|(k, _)| *k == key) {
                return Err(self.err(&format!("duplicate object key {key:?}")));
            }
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }
}

/// Length of the UTF-8 sequence starting with byte `b` (None for
/// continuation/invalid lead bytes).
fn utf8_len(b: u8) -> Option<usize> {
    match b {
        0x00..=0x7F => Some(1),
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_containers() {
        let j = parse(r#"{"a": 1, "b": [true, null, "x"], "c": -2.5}"#).unwrap();
        assert_eq!(j.get("a").and_then(Json::as_i64), Some(1));
        assert_eq!(j.get("b").and_then(Json::as_arr).map(<[Json]>::len), Some(3));
        assert_eq!(j.get("c"), Some(&Json::Num(-2.5)));
    }

    #[test]
    fn compact_round_trips() {
        let text = r#"{"name": "a/b", "kernel": [3, 3], "pad": 0}"#;
        let j = parse(text).unwrap();
        assert_eq!(j.to_compact(), text);
        assert_eq!(parse(&j.to_compact()).unwrap(), j);
    }

    #[test]
    fn string_escapes_round_trip() {
        let j = parse(r#""a\"b\\c\nA""#).unwrap();
        assert_eq!(j, Json::Str("a\"b\\c\nA".to_string()));
        assert_eq!(parse(&j.to_compact()).unwrap(), j);
    }

    #[test]
    fn errors_carry_line_and_column() {
        let err = parse("{\n  \"a\": nope\n}").unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn rejects_trailing_garbage_and_duplicates() {
        assert!(parse("{} x").is_err());
        assert!(parse(r#"{"a": 1, "a": 2}"#).is_err());
        assert!(parse("[1, 2").is_err());
    }

    #[test]
    fn absurd_nesting_is_an_error_not_a_stack_overflow() {
        let deep = "[".repeat(100_000) + &"]".repeat(100_000);
        let err = parse(&deep).unwrap_err().to_string();
        assert!(err.contains("nesting"), "{err}");
    }

    #[test]
    fn surrogate_pairs_decode_and_invalid_pairs_error() {
        assert_eq!(parse(r#""😀""#).unwrap(), Json::Str("😀".to_string()));
        assert_eq!(parse(r#""\ud83d\ude00""#).unwrap(), Json::Str("😀".to_string()));
        assert!(parse(r#""\ud800A""#).is_err(), "high surrogate + non-surrogate");
        assert!(parse(r#""\ud800x""#).is_err(), "unpaired surrogate");
    }

    #[test]
    fn integers_only_for_as_i64() {
        assert_eq!(parse("3").unwrap().as_i64(), Some(3));
        assert_eq!(parse("3.5").unwrap().as_i64(), None);
    }
}
