//! Model frontend: declarative spec files → executable networks.
//!
//! The paper's whole-life-cost argument (§2, §6) rests on the
//! *generality* of the GCONV Chain: one accelerator stack should absorb
//! "all kinds of existing and emerging layers" without per-network
//! engineering. This module makes that real for the repo: instead of a
//! hand-written Rust builder per network, any CNN can be described as a
//! versioned JSON spec file and lowered through the unchanged
//! `lower_network` → `ChainExec` / `Session` path.
//!
//! * [`json`] — a small self-contained JSON reader/writer (no parsing
//!   crates exist in the offline dependency set).
//! * [`spec`] — the versioned spec format ([`ModelSpec`]): layer list,
//!   attributes, optional declared partial outputs.
//! * [`infer`] — analyser-style parameter + shape inference:
//!   defaults, derivation of omitted attributes from declared facts,
//!   panic-free shape validation, and declared-vs-inferred unification
//!   with layer-name + field context on every failure.
//! * [`build`] — spec → [`crate::ir::Network`] construction (with an
//!   optional batch override for the serving engine).
//! * [`export`] — network → canonical spec. The seven benchmark
//!   builders are exported into bundled files under `rust/specs/`, the
//!   round-trip conformance oracle.
//!
//! Entry points: [`load_spec`] / [`ModelSpec::parse_json`] to read,
//! [`build_network`] / [`build_with_batch`] to construct,
//! [`export_network`] to write, [`discover_specs`] to enumerate the
//! bundled spec directory (`rust/specs/`, overridable via
//! `GCONV_SPEC_DIR`). `networks::resolve` and
//! `exec::serve::Engine::register_spec` wire specs into the CLI and
//! the serving engine.

pub mod build;
pub mod export;
pub mod infer;
pub mod json;
pub mod spec;

use std::path::{Path, PathBuf};

use anyhow::Result;

pub use build::{build_network, build_with_batch};
pub use export::{export_json, export_network};
pub use spec::{Attr, LayerSpec, ModelSpec};

/// Directory holding the bundled spec files. Resolution order: the
/// `GCONV_SPEC_DIR` environment variable, `rust/specs` (repo root as
/// cwd), `specs` (package root as cwd — what cargo test/bench use), and
/// finally the compile-time package path (works wherever the source
/// tree still exists).
pub fn spec_dir() -> PathBuf {
    if let Some(dir) = std::env::var_os("GCONV_SPEC_DIR") {
        return PathBuf::from(dir);
    }
    for candidate in ["rust/specs", "specs"] {
        let p = PathBuf::from(candidate);
        if p.is_dir() {
            return p;
        }
    }
    Path::new(env!("CARGO_MANIFEST_DIR")).join("specs")
}

/// Bundled `.json` spec files, sorted by file name (empty when the spec
/// directory does not exist).
pub fn discover_specs() -> Vec<PathBuf> {
    let Ok(entries) = std::fs::read_dir(spec_dir()) else {
        return Vec::new();
    };
    let mut files: Vec<PathBuf> = entries
        .flatten()
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|x| x == "json"))
        .collect();
    files.sort();
    files
}

/// Load one spec file.
pub fn load_spec(path: &Path) -> Result<ModelSpec> {
    ModelSpec::load(path)
}

/// Resolve a user-supplied name to a spec file: a direct path to an
/// existing file wins, else `<spec_dir>/<name>.json`. The single
/// lookup rule every entry point (CLI run/serve, `networks::resolve`)
/// shares.
pub fn find_spec(name: &str) -> Option<PathBuf> {
    let direct = PathBuf::from(name);
    if direct.is_file() {
        return Some(direct);
    }
    let bundled = spec_dir().join(format!("{name}.json"));
    bundled.is_file().then_some(bundled)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_dir_resolves_to_an_existing_directory() {
        // In-tree runs always find the bundled directory via one of the
        // fallbacks (cargo sets cwd to the workspace or package root).
        assert!(spec_dir().is_dir(), "spec dir {:?} missing", spec_dir());
    }

    #[test]
    fn discovery_finds_the_bundled_benchmark_specs() {
        let stems: Vec<String> = discover_specs()
            .iter()
            .filter_map(|p| p.file_stem().map(|s| s.to_string_lossy().into_owned()))
            .collect();
        for code in crate::networks::BENCHMARK_CODES {
            assert!(stems.iter().any(|s| s == code), "no bundled spec for {code}: {stems:?}");
        }
    }
}
