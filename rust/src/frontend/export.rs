//! [`Network`] → spec export.
//!
//! Produces the *canonical* spec form: explicit `inputs` for every
//! layer, every attribute written out (no reliance on defaults), no
//! declared outputs. The seven benchmark builders are exported to
//! bundled files under `rust/specs/`, which the round-trip tests pin as
//! the conformance oracle: `build(parse(file))` must equal the builder
//! network node-for-node, and `export(builder)` must equal
//! `parse(file)` — so the spec reader, the exporter and the bundled
//! files can only move together.

use super::spec::{Attr, LayerSpec, ModelSpec};
use crate::ir::{Layer, Network, PoolKind};

/// Export `net` as a canonical model spec.
pub fn export_network(net: &Network) -> ModelSpec {
    let layers = net.nodes().iter().map(|node| export_layer(net, node)).collect();
    ModelSpec { name: net.name.clone(), layers }
}

/// Canonical JSON text of `net`'s spec (what the bundled files hold).
pub fn export_json(net: &Network) -> String {
    export_network(net).to_json()
}

fn export_layer(net: &Network, node: &crate::ir::LayerNode) -> LayerSpec {
    let mut ls = LayerSpec::new(&node.name, kind_name(&node.layer));
    ls.inputs = Some(node.inputs.iter().map(|&i| net.node(i).name.clone()).collect());
    match &node.layer {
        Layer::Input { shape } => ls.shape = shape.iter().collect(),
        Layer::Conv { out_channels, kernel, stride, pad, groups } => {
            int(&mut ls, "out_channels", *out_channels);
            int(&mut ls, "stride", *stride);
            int(&mut ls, "pad", *pad);
            int(&mut ls, "groups", *groups);
            list(&mut ls, "kernel", &[kernel.0, kernel.1]);
        }
        Layer::Conv3d { out_channels, kernel, stride, pad } => {
            int(&mut ls, "out_channels", *out_channels);
            int(&mut ls, "stride", *stride);
            int(&mut ls, "pad", *pad);
            list(&mut ls, "kernel", &[kernel.0, kernel.1, kernel.2]);
        }
        Layer::FullyConnected { out_features } => int(&mut ls, "out_features", *out_features),
        Layer::Pool { kind, kernel, stride, pad } => {
            int(&mut ls, "kernel", *kernel);
            int(&mut ls, "stride", *stride);
            int(&mut ls, "pad", *pad);
            pool(&mut ls, *kind);
        }
        Layer::Pool3d { kind, kernel, stride } => {
            list(&mut ls, "kernel", &[kernel.0, kernel.1, kernel.2]);
            list(&mut ls, "stride", &[stride.0, stride.1, stride.2]);
            pool(&mut ls, *kind);
        }
        Layer::Lrn { local_size } => int(&mut ls, "local_size", *local_size),
        Layer::RoiPool { num_rois, output } => {
            int(&mut ls, "num_rois", *num_rois);
            list(&mut ls, "output_size", &[output.0, output.1]);
        }
        Layer::Proposal { anchors } => int(&mut ls, "anchors", *anchors),
        Layer::PrimaryCaps { caps_channels, vec, kernel, stride } => {
            int(&mut ls, "caps_channels", *caps_channels);
            int(&mut ls, "vec", *vec);
            int(&mut ls, "kernel", *kernel);
            int(&mut ls, "stride", *stride);
        }
        Layer::DigitCaps { out_caps, out_vec, routing } => {
            int(&mut ls, "out_caps", *out_caps);
            int(&mut ls, "out_vec", *out_vec);
            int(&mut ls, "routing", *routing);
        }
        Layer::GlobalAvgPool
        | Layer::Relu
        | Layer::Sigmoid
        | Layer::Softmax
        | Layer::BatchNorm
        | Layer::Scale
        | Layer::Dropout
        | Layer::Concat
        | Layer::Eltwise => {}
    }
    ls
}

fn int(ls: &mut LayerSpec, key: &str, v: usize) {
    ls.attrs.insert(key.to_string(), Attr::Int(v as i64));
}

fn list(ls: &mut LayerSpec, key: &str, values: &[usize]) {
    let xs = values.iter().map(|&v| v as i64).collect();
    ls.attrs.insert(key.to_string(), Attr::List(xs));
}

fn pool(ls: &mut LayerSpec, kind: PoolKind) {
    let name = match kind {
        PoolKind::Max => "max",
        PoolKind::Avg => "avg",
    };
    ls.attrs.insert("pool".to_string(), Attr::Str(name.to_string()));
}

/// Spec-vocabulary kind of an IR layer (stable, unlike
/// [`Layer::kind`], which renames depthwise convolutions for reports).
fn kind_name(layer: &Layer) -> &'static str {
    match layer {
        Layer::Input { .. } => "input",
        Layer::Conv { .. } => "conv",
        Layer::Conv3d { .. } => "conv3d",
        Layer::FullyConnected { .. } => "fc",
        Layer::Pool { .. } => "pool",
        Layer::GlobalAvgPool => "global_avg_pool",
        Layer::Pool3d { .. } => "pool3d",
        Layer::Relu => "relu",
        Layer::Sigmoid => "sigmoid",
        Layer::Softmax => "softmax",
        Layer::Lrn { .. } => "lrn",
        Layer::BatchNorm => "batch_norm",
        Layer::Scale => "scale",
        Layer::Dropout => "dropout",
        Layer::Concat => "concat",
        Layer::Eltwise => "eltwise",
        Layer::RoiPool { .. } => "roi_pool",
        Layer::Proposal { .. } => "proposal",
        Layer::PrimaryCaps { .. } => "primary_caps",
        Layer::DigitCaps { .. } => "digit_caps",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::build::build_network;
    use crate::ir::Dim;
    use crate::networks::mobilenet_block;

    #[test]
    fn export_import_round_trips_the_block_helper() {
        let net = mobilenet_block(4, 16, 8);
        let spec = export_network(&net);
        assert_eq!(spec.name, "MobileNetBlock");
        assert_eq!(spec.layers.len(), net.len());
        assert_eq!(spec.layers[1].kind, "conv");
        assert_eq!(spec.layers[1].attrs["groups"], Attr::Int(16));

        let again = build_network(&spec).unwrap();
        assert_eq!(again.len(), net.len());
        for (a, b) in again.nodes().iter().zip(net.nodes()) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.layer, b.layer);
            assert_eq!(a.inputs, b.inputs);
            assert_eq!(a.output, b.output);
        }
    }

    #[test]
    fn exported_json_parses_back_to_the_same_spec() {
        let net = mobilenet_block(2, 4, 6);
        let spec = export_network(&net);
        let parsed = ModelSpec::parse_json(&spec.to_json()).unwrap();
        assert_eq!(spec, parsed);
    }

    #[test]
    fn input_shape_preserves_dimension_order() {
        let net = mobilenet_block(2, 4, 6);
        let spec = export_network(&net);
        let dims: Vec<Dim> = spec.layers[0].shape.iter().map(|&(d, _)| d).collect();
        assert_eq!(dims, vec![Dim::B, Dim::C, Dim::H, Dim::W]);
    }
}
