//! The versioned model-spec format.
//!
//! A spec file is a JSON document describing a CNN as a list of layers:
//!
//! ```json
//! {
//!   "format": "gconv-chain-model",
//!   "version": 1,
//!   "name": "TinyCNN",
//!   "layers": [
//!     {"name": "data", "kind": "input", "inputs": [],
//!      "shape": [["B", 1], ["C", 3], ["H", 16], ["W", 16]]},
//!     {"name": "conv1", "kind": "conv", "kernel": 3, "pad": 1,
//!      "output": {"C": 8}},
//!     {"name": "relu1", "kind": "relu"}
//!   ]
//! }
//! ```
//!
//! Reserved layer keys are `name`, `kind`, `inputs`, `shape` and
//! `output`; every other key is a layer attribute (integer, list of
//! integers, or string). `inputs` may be omitted — the layer then
//! consumes the previous layer, so linear chains need no explicit
//! wiring. `output` declares a *partial* output shape that the
//! [inference pass](super::infer) unifies with the propagated shapes:
//! derivable attributes (a conv's `out_channels`, an `fc`'s
//! `out_features`) may be omitted when `output` pins the corresponding
//! dimension, and any declared dimension that contradicts the inferred
//! shape is reported with layer-name + field context.
//!
//! This module is the data model + (de)serialization; shape/parameter
//! inference lives in [`super::infer`] and graph construction in
//! [`super::build`].

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use super::json::{parse, Json};
use crate::ir::Dim;

/// Document format marker every spec file must carry.
pub const FORMAT: &str = "gconv-chain-model";

/// The spec version this build reads and writes.
pub const VERSION: i64 = 1;

/// One layer attribute value.
#[derive(Clone, Debug, PartialEq)]
pub enum Attr {
    /// An integer, e.g. `"stride": 2`.
    Int(i64),
    /// A list of integers, e.g. `"kernel": [3, 3]`.
    List(Vec<i64>),
    /// A string, e.g. `"pool": "max"`.
    Str(String),
}

impl fmt::Display for Attr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Attr::Int(n) => write!(f, "{n}"),
            Attr::List(xs) => write!(f, "{xs:?}"),
            Attr::Str(s) => write!(f, "{s:?}"),
        }
    }
}

/// One layer of a model spec.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LayerSpec {
    /// Unique layer name (graph node id and weight-tensor key).
    pub name: String,
    /// Layer kind, e.g. `"conv"` — see [`super::infer`] for the set.
    pub kind: String,
    /// Producer layer names. `None` = the previous layer in the list.
    pub inputs: Option<Vec<String>>,
    /// Input-layer shape as ordered `(dim, extent)` pairs (empty for
    /// every other kind).
    pub shape: Vec<(Dim, usize)>,
    /// Declared partial output shape, unified against the inferred one.
    pub output: Vec<(Dim, usize)>,
    /// Kind-specific attributes (alphabetical when serialized).
    pub attrs: BTreeMap<String, Attr>,
}

impl LayerSpec {
    /// New layer with just a name and kind.
    pub fn new(name: &str, kind: &str) -> Self {
        LayerSpec { name: name.to_string(), kind: kind.to_string(), ..Default::default() }
    }

    /// Canonical one-line JSON rendering of this layer.
    fn to_json(&self) -> Json {
        let mut pairs: Vec<(String, Json)> = vec![
            ("name".into(), Json::Str(self.name.clone())),
            ("kind".into(), Json::Str(self.kind.clone())),
        ];
        if let Some(inputs) = &self.inputs {
            let items = inputs.iter().map(|s| Json::Str(s.clone())).collect();
            pairs.push(("inputs".into(), Json::Arr(items)));
        }
        if !self.shape.is_empty() {
            let items = self
                .shape
                .iter()
                .map(|&(d, n)| {
                    Json::Arr(vec![Json::Str(d.name().to_string()), Json::Num(n as f64)])
                })
                .collect();
            pairs.push(("shape".into(), Json::Arr(items)));
        }
        for (key, attr) in &self.attrs {
            let v = match attr {
                Attr::Int(n) => Json::Num(*n as f64),
                Attr::List(xs) => Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect()),
                Attr::Str(s) => Json::Str(s.clone()),
            };
            pairs.push((key.clone(), v));
        }
        if !self.output.is_empty() {
            let items = self
                .output
                .iter()
                .map(|&(d, n)| (d.name().to_string(), Json::Num(n as f64)))
                .collect();
            pairs.push(("output".into(), Json::Obj(items)));
        }
        Json::Obj(pairs)
    }

    fn from_json(j: &Json) -> Result<Self> {
        let Some(members) = j.as_obj() else {
            bail!("each layer must be a JSON object, found {}", j.kind());
        };
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .context("layer is missing a \"name\" string")?
            .to_string();
        ensure!(!name.is_empty(), "layer has an empty \"name\"");
        let lctx = |msg: String| format!("layer {name:?}: {msg}");
        let kind = j
            .get("kind")
            .and_then(Json::as_str)
            .with_context(|| lctx("missing a \"kind\" string".into()))?
            .to_string();
        let mut spec = LayerSpec::new(&name, &kind);
        for (key, val) in members {
            match key.as_str() {
                "name" | "kind" => {}
                "inputs" => {
                    let items = val
                        .as_arr()
                        .with_context(|| lctx("\"inputs\" must be an array of strings".into()))?;
                    let mut inputs = Vec::with_capacity(items.len());
                    for item in items {
                        let s = item.as_str().with_context(|| {
                            lctx("\"inputs\" must be an array of strings".into())
                        })?;
                        inputs.push(s.to_string());
                    }
                    spec.inputs = Some(inputs);
                }
                "shape" => spec.shape = parse_shape_pairs(&name, val)?,
                "output" => spec.output = parse_output_decl(&name, val)?,
                attr_key => {
                    let attr = parse_attr(val).with_context(|| {
                        lctx(format!(
                            "field {attr_key:?} must be an integer, a list of integers, \
                             or a string"
                        ))
                    })?;
                    spec.attrs.insert(attr_key.to_string(), attr);
                }
            }
        }
        Ok(spec)
    }
}

/// A whole model spec: name + layer list (topological order).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ModelSpec {
    /// Model name — doubles as the serving code under
    /// `Engine::register_spec`.
    pub name: String,
    /// Layers in topological order (producers before consumers).
    pub layers: Vec<LayerSpec>,
}

impl ModelSpec {
    /// Parse a spec from JSON text.
    pub fn parse_json(text: &str) -> Result<ModelSpec> {
        let doc = parse(text).context("invalid JSON")?;
        let format = doc.get("format").and_then(Json::as_str).unwrap_or("");
        ensure!(
            format == FORMAT,
            "not a model spec: expected \"format\": {FORMAT:?}, found {format:?}"
        );
        let version = doc.get("version").and_then(Json::as_i64).unwrap_or(0);
        ensure!(
            version == VERSION,
            "unsupported spec version {version} (this build reads version {VERSION})"
        );
        let name = doc
            .get("name")
            .and_then(Json::as_str)
            .context("spec is missing a \"name\" string")?
            .to_string();
        let layers = doc
            .get("layers")
            .and_then(Json::as_arr)
            .context("spec is missing a \"layers\" array")?;
        let mut spec = ModelSpec { name, layers: Vec::with_capacity(layers.len()) };
        for layer in layers {
            spec.layers.push(LayerSpec::from_json(layer)?);
        }
        Ok(spec)
    }

    /// Load a spec file (with the path in error context).
    pub fn load(path: &Path) -> Result<ModelSpec> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading spec file {}", path.display()))?;
        ModelSpec::parse_json(&text)
            .with_context(|| format!("parsing spec file {}", path.display()))
    }

    /// Canonical JSON rendering: document header on separate lines, one
    /// compact line per layer. [`ModelSpec::parse_json`] of the result
    /// is equal to `self`.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"format\": \"{FORMAT}\",\n"));
        out.push_str(&format!("  \"version\": {VERSION},\n"));
        let mut name = String::new();
        Json::Str(self.name.clone()).write_compact(&mut name);
        out.push_str(&format!("  \"name\": {name},\n"));
        out.push_str("  \"layers\": [\n");
        for (i, layer) in self.layers.iter().enumerate() {
            out.push_str("    ");
            layer.to_json().write_compact(&mut out);
            out.push_str(if i + 1 < self.layers.len() { ",\n" } else { "\n" });
        }
        out.push_str("  ]\n}\n");
        out
    }
}

/// Parse a dimension name (`"B"`, `"C"`, `"H"`, `"W"`, `"T"`, `"V"`).
pub fn parse_dim(name: &str, s: &str) -> Result<Dim> {
    match s {
        "B" => Ok(Dim::B),
        "C" => Ok(Dim::C),
        "H" => Ok(Dim::H),
        "W" => Ok(Dim::W),
        "T" => Ok(Dim::T),
        "V" => Ok(Dim::V),
        other => bail!("layer {name:?}: unknown dimension {other:?} (expected B/C/H/W/T/V)"),
    }
}

/// `"shape": [["B", 32], ["C", 3], …]` — ordered, positive, unique.
fn parse_shape_pairs(name: &str, val: &Json) -> Result<Vec<(Dim, usize)>> {
    let items = val
        .as_arr()
        .with_context(|| format!("layer {name:?}: \"shape\" must be a [[dim, extent], …] array"))?;
    let mut pairs = Vec::with_capacity(items.len());
    for item in items {
        let pair = item.as_arr().unwrap_or(&[]);
        let (Some(d), Some(n)) = (
            pair.first().and_then(Json::as_str),
            pair.get(1).and_then(Json::as_i64),
        ) else {
            bail!("layer {name:?}: each \"shape\" entry must be a [dim, extent] pair");
        };
        let dim = parse_dim(name, d)?;
        ensure!(n > 0, "layer {name:?}: shape extent {dim} = {n} must be positive");
        ensure!(
            pairs.iter().all(|&(x, _)| x != dim),
            "layer {name:?}: duplicate shape dimension {dim}"
        );
        pairs.push((dim, n as usize));
    }
    Ok(pairs)
}

/// `"output": {"C": 96, "H": 55}` — a partial declared output shape.
fn parse_output_decl(name: &str, val: &Json) -> Result<Vec<(Dim, usize)>> {
    let members = val
        .as_obj()
        .with_context(|| format!("layer {name:?}: \"output\" must be a {{dim: extent}} object"))?;
    let mut pairs = Vec::with_capacity(members.len());
    for (key, v) in members {
        let dim = parse_dim(name, key)?;
        let n = v.as_i64().unwrap_or(0);
        ensure!(n > 0, "layer {name:?}: declared output {dim} must be a positive integer");
        pairs.push((dim, n as usize));
    }
    Ok(pairs)
}

fn parse_attr(val: &Json) -> Result<Attr> {
    match val {
        Json::Num(_) => Ok(Attr::Int(val.as_i64().context("not an integer")?)),
        Json::Str(s) => Ok(Attr::Str(s.clone())),
        Json::Arr(items) => {
            let mut xs = Vec::with_capacity(items.len());
            for item in items {
                xs.push(item.as_i64().context("not an integer")?);
            }
            Ok(Attr::List(xs))
        }
        other => bail!("unsupported value type {}", other.kind()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const TINY: &str = r#"{
      "format": "gconv-chain-model",
      "version": 1,
      "name": "t",
      "layers": [
        {"name": "data", "kind": "input", "inputs": [],
         "shape": [["B", 2], ["C", 3], ["H", 8], ["W", 8]]},
        {"name": "conv1", "kind": "conv", "kernel": [3, 3], "pad": 1, "output": {"C": 4}},
        {"name": "relu1", "kind": "relu"}
      ]
    }"#;

    #[test]
    fn parses_layers_defaults_and_decls() {
        let spec = ModelSpec::parse_json(TINY).unwrap();
        assert_eq!(spec.name, "t");
        assert_eq!(spec.layers.len(), 3);
        assert_eq!(spec.layers[0].inputs, Some(vec![]));
        assert_eq!(spec.layers[0].shape[1], (Dim::C, 3));
        assert_eq!(spec.layers[1].inputs, None, "omitted inputs default to previous");
        assert_eq!(spec.layers[1].attrs["kernel"], Attr::List(vec![3, 3]));
        assert_eq!(spec.layers[1].output, vec![(Dim::C, 4)]);
    }

    #[test]
    fn json_round_trip_is_identity() {
        let spec = ModelSpec::parse_json(TINY).unwrap();
        let again = ModelSpec::parse_json(&spec.to_json()).unwrap();
        assert_eq!(spec, again);
    }

    #[test]
    fn version_and_format_are_enforced() {
        let bad = TINY.replace("\"version\": 1", "\"version\": 2");
        let err = ModelSpec::parse_json(&bad).unwrap_err().to_string();
        assert!(err.contains("version 2"), "{err}");
        let bad = TINY.replace("gconv-chain-model", "something-else");
        assert!(ModelSpec::parse_json(&bad).is_err());
    }

    #[test]
    fn shape_errors_name_the_layer() {
        let bad = TINY.replace("[\"B\", 2]", "[\"B\", 0]");
        let err = ModelSpec::parse_json(&bad).unwrap_err().to_string();
        assert!(err.contains("\"data\"") && err.contains("positive"), "{err}");
        let bad = TINY.replace("[\"H\", 8]", "[\"Q\", 8]");
        let err = ModelSpec::parse_json(&bad).unwrap_err().to_string();
        assert!(err.contains("unknown dimension"), "{err}");
    }

    #[test]
    fn attr_type_errors_are_targeted() {
        let bad = TINY.replace("\"pad\": 1", "\"pad\": true");
        let err = format!("{:#}", ModelSpec::parse_json(&bad).unwrap_err());
        assert!(err.contains("\"conv1\"") && err.contains("\"pad\""), "{err}");
    }
}
