//! Analyser-style parameter + shape inference over model specs.
//!
//! Mirrors the rule-based analyser idiom of tract: every layer kind
//! contributes (a) *parameter rules* — which attributes exist, their
//! defaults, and how omitted ones are derived from declared facts
//! (e.g. a conv's `out_channels` from a declared output `C`) — and
//! (b) *shape rules* — panic-free preconditions plus the output shape,
//! unified against any declared partial output. Failures carry
//! layer-name + field context instead of the `assert!`s the
//! builder-facing [`crate::ir::Layer::infer_shape`] uses, so a
//! malformed spec file is a diagnosable error, never a panic.

use anyhow::{bail, ensure, Result};

use super::spec::{Attr, LayerSpec};
use crate::ir::{Dim, Layer, PoolKind, Shape};

/// Layer kinds the spec format understands, in spec vocabulary.
pub const KINDS: [&str; 20] = [
    "input",
    "conv",
    "conv3d",
    "fc",
    "pool",
    "pool3d",
    "global_avg_pool",
    "relu",
    "sigmoid",
    "softmax",
    "lrn",
    "batch_norm",
    "scale",
    "dropout",
    "concat",
    "eltwise",
    "roi_pool",
    "proposal",
    "primary_caps",
    "digit_caps",
];

/// Attribute accessors scoped to one layer, so every error carries
/// `layer 'name' (kind)` context.
struct Attrs<'a> {
    ls: &'a LayerSpec,
}

impl Attrs<'_> {
    fn ctx(&self) -> String {
        format!("layer {:?} ({})", self.ls.name, self.ls.kind)
    }

    /// Positive integer attribute, if present.
    fn opt(&self, key: &str) -> Result<Option<usize>> {
        match self.ls.attrs.get(key) {
            None => Ok(None),
            Some(Attr::Int(n)) if *n > 0 => Ok(Some(*n as usize)),
            Some(Attr::Int(n)) => bail!("{}: {key} = {n} must be positive", self.ctx()),
            Some(other) => bail!("{}: {key} = {other} must be a positive integer", self.ctx()),
        }
    }

    /// Non-negative integer attribute with a default (paddings).
    fn non_negative(&self, key: &str, default: usize) -> Result<usize> {
        match self.ls.attrs.get(key) {
            None => Ok(default),
            Some(Attr::Int(n)) if *n >= 0 => Ok(*n as usize),
            Some(other) => {
                bail!("{}: {key} = {other} must be a non-negative integer", self.ctx())
            }
        }
    }

    /// Positive integer attribute with a default.
    fn or(&self, key: &str, default: usize) -> Result<usize> {
        Ok(self.opt(key)?.unwrap_or(default))
    }

    /// Required positive integer attribute.
    fn require(&self, key: &str) -> Result<usize> {
        self.opt(key)?
            .ok_or_else(|| anyhow::anyhow!("{}: missing required field {key:?}", self.ctx()))
    }

    /// Integer or N-list attribute broadcast to exactly `n` values
    /// (`"kernel": 3` ≡ `"kernel": [3, 3]` for a 2-D layer).
    fn tuple(&self, key: &str, n: usize) -> Result<Option<Vec<usize>>> {
        let values = match self.ls.attrs.get(key) {
            None => return Ok(None),
            Some(Attr::Int(v)) => vec![*v; n],
            Some(Attr::List(xs)) => {
                ensure!(
                    xs.len() == n,
                    "{}: {key} must hold {n} values, found {}",
                    self.ctx(),
                    xs.len()
                );
                xs.clone()
            }
            Some(other) => {
                bail!("{}: {key} = {other} must be an integer or a {n}-list", self.ctx())
            }
        };
        ensure!(
            values.iter().all(|&v| v > 0),
            "{}: every {key} value must be positive, found {values:?}",
            self.ctx()
        );
        Ok(Some(values.iter().map(|&v| v as usize).collect()))
    }

    fn require_tuple(&self, key: &str, n: usize) -> Result<Vec<usize>> {
        self.tuple(key, n)?
            .ok_or_else(|| anyhow::anyhow!("{}: missing required field {key:?}", self.ctx()))
    }

    /// The declared output extent of `d`, if any (the derivation source
    /// for omitted `out_channels`/`out_features`).
    fn declared(&self, d: Dim) -> Option<usize> {
        self.ls.output.iter().find(|&&(x, _)| x == d).map(|&(_, n)| n)
    }

    /// `out_channels`-style attribute: explicit, or derived from a
    /// declared output dimension.
    fn channels_like(&self, key: &str, from: Dim) -> Result<usize> {
        if let Some(n) = self.opt(key)? {
            return Ok(n);
        }
        self.declared(from).ok_or_else(|| {
            anyhow::anyhow!(
                "{}: missing field {key:?} and no declared \"output\" {from} to infer it from",
                self.ctx()
            )
        })
    }

    /// Pooling flavour attribute (`"pool": "max" | "avg"`).
    fn pool_kind(&self) -> Result<PoolKind> {
        match self.ls.attrs.get("pool") {
            None => Ok(PoolKind::Max),
            Some(Attr::Str(s)) if s == "max" => Ok(PoolKind::Max),
            Some(Attr::Str(s)) if s == "avg" => Ok(PoolKind::Avg),
            Some(other) => bail!("{}: pool = {other} must be \"max\" or \"avg\"", self.ctx()),
        }
    }

    /// Reject attribute keys the kind does not define (typo guard).
    fn allow_only(&self, allowed: &[&str]) -> Result<()> {
        for key in self.ls.attrs.keys() {
            ensure!(
                allowed.contains(&key.as_str()),
                "{}: unknown field {key:?} (this kind takes {allowed:?})",
                self.ctx()
            );
        }
        Ok(())
    }
}

/// Build the [`Layer`] a spec layer describes, applying defaults and
/// deriving omitted attributes from declared facts. Shape-dependent
/// validation happens later in [`check_layer`].
pub fn layer_from_spec(ls: &LayerSpec) -> Result<Layer> {
    let a = Attrs { ls };
    // `shape` is reserved for input layers; anywhere else it would be
    // silently ignored, so reject it like any other stray field.
    ensure!(
        ls.kind == "input" || ls.shape.is_empty(),
        "{}: \"shape\" only applies to input layers (declare expectations via \"output\")",
        a.ctx()
    );
    match ls.kind.as_str() {
        "input" => {
            a.allow_only(&[])?;
            ensure!(
                !ls.shape.is_empty(),
                "{}: input layers need a \"shape\" of [dim, extent] pairs",
                a.ctx()
            );
            Ok(Layer::Input { shape: Shape::new(&ls.shape) })
        }
        "conv" => {
            a.allow_only(&["out_channels", "kernel", "stride", "pad", "groups"])?;
            let k = a.require_tuple("kernel", 2)?;
            Ok(Layer::Conv {
                out_channels: a.channels_like("out_channels", Dim::C)?,
                kernel: (k[0], k[1]),
                stride: a.or("stride", 1)?,
                pad: a.non_negative("pad", 0)?,
                groups: a.or("groups", 1)?,
            })
        }
        "conv3d" => {
            a.allow_only(&["out_channels", "kernel", "stride", "pad"])?;
            let k = a.require_tuple("kernel", 3)?;
            Ok(Layer::Conv3d {
                out_channels: a.channels_like("out_channels", Dim::C)?,
                kernel: (k[0], k[1], k[2]),
                stride: a.or("stride", 1)?,
                pad: a.non_negative("pad", 0)?,
            })
        }
        "fc" => {
            a.allow_only(&["out_features"])?;
            Ok(Layer::FullyConnected {
                out_features: a.channels_like("out_features", Dim::C)?,
            })
        }
        "pool" => {
            a.allow_only(&["pool", "kernel", "stride", "pad"])?;
            let kernel = a.require("kernel")?;
            Ok(Layer::Pool {
                kind: a.pool_kind()?,
                kernel,
                stride: a.or("stride", kernel)?,
                pad: a.non_negative("pad", 0)?,
            })
        }
        "pool3d" => {
            a.allow_only(&["pool", "kernel", "stride"])?;
            let k = a.require_tuple("kernel", 3)?;
            let s = a.tuple("stride", 3)?.unwrap_or_else(|| k.clone());
            Ok(Layer::Pool3d {
                kind: a.pool_kind()?,
                kernel: (k[0], k[1], k[2]),
                stride: (s[0], s[1], s[2]),
            })
        }
        "global_avg_pool" => {
            a.allow_only(&[])?;
            Ok(Layer::GlobalAvgPool)
        }
        "relu" => {
            a.allow_only(&[])?;
            Ok(Layer::Relu)
        }
        "sigmoid" => {
            a.allow_only(&[])?;
            Ok(Layer::Sigmoid)
        }
        "softmax" => {
            a.allow_only(&[])?;
            Ok(Layer::Softmax)
        }
        "lrn" => {
            a.allow_only(&["local_size"])?;
            let local_size = a.or("local_size", 5)?;
            ensure!(
                local_size % 2 == 1,
                "{}: local_size = {local_size} must be odd (the GCONV lowering centres \
                 the window over the channel axis)",
                a.ctx()
            );
            Ok(Layer::Lrn { local_size })
        }
        "batch_norm" => {
            a.allow_only(&[])?;
            Ok(Layer::BatchNorm)
        }
        "scale" => {
            a.allow_only(&[])?;
            Ok(Layer::Scale)
        }
        "dropout" => {
            a.allow_only(&[])?;
            Ok(Layer::Dropout)
        }
        "concat" => {
            a.allow_only(&[])?;
            Ok(Layer::Concat)
        }
        "eltwise" => {
            a.allow_only(&[])?;
            Ok(Layer::Eltwise)
        }
        "roi_pool" => {
            a.allow_only(&["num_rois", "output_size"])?;
            let out = a.require_tuple("output_size", 2)?;
            Ok(Layer::RoiPool { num_rois: a.require("num_rois")?, output: (out[0], out[1]) })
        }
        "proposal" => {
            a.allow_only(&["anchors"])?;
            Ok(Layer::Proposal { anchors: a.require("anchors")? })
        }
        "primary_caps" => {
            a.allow_only(&["caps_channels", "vec", "kernel", "stride"])?;
            Ok(Layer::PrimaryCaps {
                caps_channels: a.require("caps_channels")?,
                vec: a.require("vec")?,
                kernel: a.require("kernel")?,
                stride: a.or("stride", 1)?,
            })
        }
        "digit_caps" => {
            a.allow_only(&["out_caps", "out_vec", "routing"])?;
            Ok(Layer::DigitCaps {
                out_caps: a.require("out_caps")?,
                out_vec: a.require("out_vec")?,
                routing: a.or("routing", 3)?,
            })
        }
        other => bail!(
            "layer {:?}: unknown kind {other:?} (known kinds: {})",
            ls.name,
            KINDS.join(", ")
        ),
    }
}

/// One conv/pool axis must fit its padded input.
fn check_window(name: &str, axis: Dim, input: usize, kernel: usize, pad: usize) -> Result<()> {
    ensure!(
        input + 2 * pad >= kernel,
        "layer {name:?}: {axis} kernel {kernel} exceeds the padded input \
         ({input} + 2·{pad})"
    );
    Ok(())
}

/// Panic-free shape inference: validate every precondition
/// [`Layer::infer_shape`] asserts, then return the inferred output
/// shape. After this succeeds, `infer_shape` cannot panic.
pub fn check_layer(name: &str, layer: &Layer, inputs: &[&Shape]) -> Result<Shape> {
    let arity_one = || -> Result<&Shape> {
        ensure!(
            inputs.len() == 1,
            "layer {name:?}: {} expects exactly one input, found {}",
            layer.kind(),
            inputs.len()
        );
        Ok(inputs[0])
    };
    match layer {
        Layer::Input { shape } => {
            ensure!(inputs.is_empty(), "layer {name:?}: input layers take no inputs");
            ensure!(
                shape.iter().all(|(_, n)| n > 0),
                "layer {name:?}: every input extent must be positive"
            );
        }
        Layer::Conv { out_channels, kernel, pad, groups, .. } => {
            let s = arity_one()?;
            let ic = s.extent(Dim::C);
            ensure!(
                ic % groups == 0,
                "layer {name:?}: input channels {ic} not divisible by groups {groups}"
            );
            ensure!(
                out_channels % groups == 0,
                "layer {name:?}: out_channels {out_channels} not divisible by groups {groups}"
            );
            check_window(name, Dim::H, s.extent(Dim::H), kernel.0, *pad)?;
            check_window(name, Dim::W, s.extent(Dim::W), kernel.1, *pad)?;
        }
        Layer::Conv3d { kernel, pad, .. } => {
            let s = arity_one()?;
            check_window(name, Dim::T, s.extent(Dim::T), kernel.0, *pad)?;
            check_window(name, Dim::H, s.extent(Dim::H), kernel.1, *pad)?;
            check_window(name, Dim::W, s.extent(Dim::W), kernel.2, *pad)?;
        }
        Layer::Pool { kernel, pad, .. } => {
            let s = arity_one()?;
            check_window(name, Dim::H, s.extent(Dim::H), *kernel, *pad)?;
            check_window(name, Dim::W, s.extent(Dim::W), *kernel, *pad)?;
        }
        Layer::Pool3d { kernel, .. } => {
            let s = arity_one()?;
            check_window(name, Dim::T, s.extent(Dim::T), kernel.0, 0)?;
            check_window(name, Dim::H, s.extent(Dim::H), kernel.1, 0)?;
            check_window(name, Dim::W, s.extent(Dim::W), kernel.2, 0)?;
        }
        Layer::Concat => {
            ensure!(!inputs.is_empty(), "layer {name:?}: concat needs at least one input");
            let base = inputs[0];
            for (i, s) in inputs.iter().enumerate() {
                for d in [Dim::B, Dim::H, Dim::W, Dim::T, Dim::V] {
                    ensure!(
                        s.extent(d) == base.extent(d),
                        "layer {name:?}: concat input #{i} disagrees on {d} \
                         ({} vs {})",
                        s.extent(d),
                        base.extent(d)
                    );
                }
            }
        }
        Layer::Eltwise => {
            ensure!(!inputs.is_empty(), "layer {name:?}: eltwise needs at least one input");
            for (i, s) in inputs.iter().enumerate() {
                ensure!(
                    **s == *inputs[0],
                    "layer {name:?}: eltwise input #{i} shape {s} differs from {}",
                    inputs[0]
                );
            }
        }
        Layer::PrimaryCaps { kernel, .. } => {
            let s = arity_one()?;
            check_window(name, Dim::H, s.extent(Dim::H), *kernel, 0)?;
            check_window(name, Dim::W, s.extent(Dim::W), *kernel, 0)?;
        }
        // Element-wise and head layers only need the arity check.
        _ => {
            arity_one()?;
        }
    }
    Ok(layer.infer_shape(inputs))
}

/// Unify the inferred output shape with the declared partial one.
pub fn unify_output(name: &str, inferred: &Shape, declared: &[(Dim, usize)]) -> Result<()> {
    for &(d, n) in declared {
        ensure!(
            inferred.extent(d) == n,
            "layer {name:?}: declared output {d} = {n}, but inference produced {d} = {} \
             (full inferred shape {inferred})",
            inferred.extent(d)
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::spec::ModelSpec;

    fn layer_of(json: &str) -> Result<Layer> {
        let doc = format!(
            "{{\"format\": \"gconv-chain-model\", \"version\": 1, \"name\": \"t\", \
             \"layers\": [{json}]}}"
        );
        let spec = ModelSpec::parse_json(&doc)?;
        layer_from_spec(&spec.layers[0])
    }

    #[test]
    fn conv_defaults_and_square_kernel() {
        let l = layer_of(r#"{"name": "c", "kind": "conv", "out_channels": 8, "kernel": 3}"#)
            .unwrap();
        assert_eq!(
            l,
            Layer::Conv { out_channels: 8, kernel: (3, 3), stride: 1, pad: 0, groups: 1 }
        );
    }

    #[test]
    fn conv_out_channels_derive_from_declared_output() {
        let l = layer_of(
            r#"{"name": "c", "kind": "conv", "kernel": [5, 3], "output": {"C": 12}}"#,
        )
        .unwrap();
        let want =
            Layer::Conv { out_channels: 12, kernel: (5, 3), stride: 1, pad: 0, groups: 1 };
        assert_eq!(l, want);
    }

    #[test]
    fn missing_required_fields_are_named() {
        let err = layer_of(r#"{"name": "c", "kind": "conv", "kernel": 3}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("\"c\"") && err.contains("out_channels"), "{err}");
        let err = layer_of(r#"{"name": "p", "kind": "pool"}"#).unwrap_err().to_string();
        assert!(err.contains("\"p\"") && err.contains("\"kernel\""), "{err}");
    }

    #[test]
    fn unknown_kind_and_unknown_field_are_named() {
        let err = layer_of(r#"{"name": "x", "kind": "swish"}"#).unwrap_err().to_string();
        assert!(err.contains("unknown kind \"swish\""), "{err}");
        let err = layer_of(r#"{"name": "c", "kind": "conv", "kernal": 3, "out_channels": 4}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown field \"kernal\""), "{err}");
    }

    #[test]
    fn pool_stride_defaults_to_kernel() {
        let l = layer_of(r#"{"name": "p", "kind": "pool", "kernel": 2}"#).unwrap();
        assert_eq!(l, Layer::Pool { kind: PoolKind::Max, kernel: 2, stride: 2, pad: 0 });
        let l = layer_of(
            r#"{"name": "p", "kind": "pool", "pool": "avg", "kernel": 3, "stride": 2, "pad": 1}"#,
        )
        .unwrap();
        assert_eq!(l, Layer::Pool { kind: PoolKind::Avg, kernel: 3, stride: 2, pad: 1 });
    }

    #[test]
    fn shape_on_non_input_layers_is_rejected() {
        let err = layer_of(
            r#"{"name": "c", "kind": "conv", "out_channels": 4, "kernel": 3,
                "shape": [["C", 16]]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("\"c\"") && err.contains("input layers"), "{err}");
    }

    #[test]
    fn lrn_rejects_even_windows() {
        let err = layer_of(r#"{"name": "n", "kind": "lrn", "local_size": 4}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("odd"), "{err}");
    }

    #[test]
    fn check_layer_reports_oversized_kernels() {
        let conv = Layer::Conv { out_channels: 4, kernel: (9, 9), stride: 1, pad: 0, groups: 1 };
        let s = Shape::bchw(1, 3, 8, 8);
        let err = check_layer("c1", &conv, &[&s]).unwrap_err().to_string();
        assert!(err.contains("\"c1\"") && err.contains("kernel 9"), "{err}");
    }

    #[test]
    fn check_layer_reports_group_mismatches() {
        let conv = Layer::Conv { out_channels: 4, kernel: (3, 3), stride: 1, pad: 1, groups: 3 };
        let s = Shape::bchw(1, 4, 8, 8);
        let err = check_layer("c1", &conv, &[&s]).unwrap_err().to_string();
        assert!(err.contains("not divisible by groups 3"), "{err}");
    }

    #[test]
    fn unify_reports_dim_and_values() {
        let inferred = Shape::bchw(1, 16, 8, 8);
        let err = unify_output("c1", &inferred, &[(Dim::C, 12)]).unwrap_err().to_string();
        assert!(err.contains("declared output C = 12") && err.contains("C = 16"), "{err}");
        unify_output("c1", &inferred, &[(Dim::C, 16), (Dim::H, 8)]).unwrap();
    }
}
