//! Integration tests of the whole simulator stack: the qualitative
//! claims of the paper's evaluation must hold on the full benchmarks.

use gconv_chain::accel::configs::by_code;
use gconv_chain::networks::benchmark;
use gconv_chain::report::geomean;
use gconv_chain::sim::{simulate, ExecMode, SimOptions};

fn sim(net: &str, accel: &str, mode: ExecMode) -> gconv_chain::sim::SimResult {
    simulate(&benchmark(net), &by_code(accel), SimOptions { mode, training: true })
}

#[test]
fn headline_speedup_in_paper_band() {
    // Paper: 3.4x average, 8.2x max. Accept the right order of magnitude.
    let cells = [
        ("AN", "TPU"),
        ("AN", "DNNW"),
        ("AN", "ER"),
        ("AN", "EP"),
        ("AN", "NLR"),
        ("MN", "DNNW"),
        ("DN", "EP"),
        ("GLN", "NLR"),
    ];
    let speedups: Vec<f64> = cells
        .iter()
        .map(|(n, a)| {
            let b = sim(n, a, ExecMode::Baseline);
            let g = sim(n, a, ExecMode::GconvChain);
            b.seconds / g.seconds
        })
        .collect();
    let avg = geomean(&speedups);
    assert!((1.5..8.0).contains(&avg), "average speedup {avg:.2} out of band");
}

#[test]
fn gconv_chain_wins_biggest_on_lip_and_ep() {
    // Fig. 14: "The speedup of DN and MN on DNNW and EP are high because
    // their baselines suffer the most from the pipeline bubbles and
    // offloading."
    for n in ["DN", "MN"] {
        for a in ["DNNW", "EP"] {
            let b = sim(n, a, ExecMode::Baseline);
            let g = sim(n, a, ExecMode::GconvChain);
            let s = b.seconds / g.seconds;
            assert!(s > 2.0, "{n}/{a} speedup {s:.2} should be large");
        }
    }
}

#[test]
fn er_and_tpu_speedups_are_modest() {
    // Fig. 13/14: "The speedup over baseline TPU and ER are low because
    // they explore flexible unrolling strategies."
    for n in ["AN", "GLN", "DN"] {
        for a in ["ER", "TPU"] {
            let b = sim(n, a, ExecMode::Baseline);
            let g = sim(n, a, ExecMode::GconvChain);
            let s = b.seconds / g.seconds;
            assert!((0.8..3.0).contains(&s), "{n}/{a} speedup {s:.2} should be modest");
        }
    }
}

#[test]
fn conv_layers_no_worse_than_baseline() {
    // Fig. 13: "In all the cases, the performance of GCONV Chain is no
    // worse than the baselines" on convolution layers (5% tolerance for
    // model noise).
    for n in ["AN", "GLN", "MN"] {
        for a in ["ER", "EP", "NLR", "DNNW"] {
            let b = sim(n, a, ExecMode::Baseline);
            let g = sim(n, a, ExecMode::GconvChain);
            assert!(
                g.conv_seconds <= b.conv_seconds * 1.05,
                "{n}/{a}: GCONV conv time {} > baseline {}",
                g.conv_seconds,
                b.conv_seconds
            );
        }
    }
}

#[test]
fn depthwise_speedup_salient_on_mn() {
    // Fig. 13: "In MN, where the feature maps unrolling in the baselines
    // is useless for depthwise convolution, the speedup is salient" (NLR
    // baseline only unrolls feature maps).
    let b = sim("MN", "NLR", ExecMode::Baseline);
    let g = sim("MN", "NLR", ExecMode::GconvChain);
    assert!(b.conv_seconds / g.conv_seconds > 1.2);
}

#[test]
fn offloading_eliminated_by_gconv_chain() {
    // Benefit (2) of §1: GC-CIPs eliminate the costly offloading.
    for n in ["AN", "DN", "MN", "CapNN"] {
        for a in ["ER", "EP", "NLR"] {
            let b = sim(n, a, ExecMode::Baseline);
            let g = sim(n, a, ExecMode::GconvChain);
            assert!(b.movement.offload > 0.0, "{n}/{a} baseline must offload");
            assert_eq!(g.movement.offload, 0.0, "{n}/{a} GCONV must not offload");
        }
    }
}

#[test]
fn gc_cip_energy_beats_tip_and_lip() {
    // Fig. 19 ordering: GC-CIP ≥ TIP ≥ ... on energy efficiency (MAC per
    // energy unit), network-averaged.
    let eff = |r: &gconv_chain::sim::SimResult| r.energy.compute / r.energy.total();
    let mut gc = Vec::new();
    let mut tip = Vec::new();
    let mut lip = Vec::new();
    for n in ["AN", "GLN", "DN", "MN"] {
        gc.push(eff(&sim(n, "ER", ExecMode::GconvChain)));
        tip.push(eff(&sim(n, "TPU", ExecMode::Baseline)));
        lip.push(eff(&sim(n, "DNNW", ExecMode::Baseline)));
    }
    assert!(geomean(&gc) > geomean(&tip), "GC-CIP must beat TIP on efficiency");
    assert!(geomean(&gc) > geomean(&lip), "GC-CIP must beat LIP on efficiency");
}

#[test]
fn dnnw_baseline_underutilized_on_heterogeneous_nets() {
    // Table 1(b)/Fig. 12: the LIP pipeline utilization collapses when
    // the traditional/non-traditional balance mismatches the partition.
    let an = sim("AN", "DNNW", ExecMode::Baseline).utilization;
    let mn = sim("MN", "DNNW", ExecMode::Baseline).utilization;
    assert!(an > mn, "AN util {an:.2} should exceed MN util {mn:.2}");
}

#[test]
fn ablations_never_beat_full_chain() {
    for n in ["AN", "MN"] {
        let full = sim(n, "ER", ExecMode::GconvChain);
        let nofuse = sim(n, "ER", ExecMode::GconvNoFusion);
        let nocons = sim(n, "ER", ExecMode::GconvNoConsistent);
        assert!(full.seconds <= nofuse.seconds * 1.001, "{n}: fusion must not hurt");
        assert!(full.seconds <= nocons.seconds * 1.001, "{n}: consistency must not hurt");
        assert!(full.chain_len <= nofuse.chain_len);
    }
}

#[test]
fn training_dominates_inference() {
    for n in ["AN", "MN"] {
        let t = simulate(
            &benchmark(n),
            &by_code("ER"),
            SimOptions { mode: ExecMode::GconvChain, training: true },
        );
        let i = simulate(
            &benchmark(n),
            &by_code("ER"),
            SimOptions { mode: ExecMode::GconvChain, training: false },
        );
        assert!(t.seconds > 1.8 * i.seconds, "{n}: training {} vs inference {}", t.seconds, i.seconds);
    }
}
