//! Golden and differential tests for the native GCONV execution engine:
//! lowered conv / pool / BN / FC / softmax chains checked against small
//! hand-computed fixtures; a property test that a lowered FP convolution
//! matches a naive direct-convolution reference; and property tests that
//! the fast execution tiers (blocked dot/GEMM, odometer indexing) match
//! the naive per-element oracle **bit-for-bit** across randomized GCONV
//! shapes covering stride > 1, padding, groups, broadcast operands and
//! every `pre`/`main`/`reduce`/`post` combination the lowering emits.
//!
//! The fixtures pin the *interpreter semantics* documented in
//! `exec::interp` (Eq. 1 index arithmetic, zero padding under `Add`,
//! padding-skip under `Max`, the fixed LUT definitions). For conv, FC,
//! pooling and softmax those coincide with the textbook operators.

use gconv_chain::exec::{
    eval_gconv, eval_gconv_naive, eval_gconv_with_precision, lut_apply, plan_tier, ChainExec,
    KernelTier, Precision, Tensor, FAST_REL_TOL, GEMM_MIN_REDUCTION,
};
use gconv_chain::gconv::chain::{ChainEntry, GconvChain, Phase};
use gconv_chain::gconv::lower::{lower_network, Mode};
use gconv_chain::gconv::op::{DataRef, DimParams, GconvOp, MainOp, PostOp, PreOp, ReduceOp};
use gconv_chain::ir::{Dim, Layer, Network, PoolKind, Shape};
use gconv_chain::mapping::fuse_executable;
use gconv_chain::networks::mobilenet_block;
use gconv_chain::prop::{prop_check, Rng};

/// Build a one-layer network `Input(shape) → layer`, lower it for
/// inference, and return its executor (strict: tests provide tensors).
fn single_layer(shape: Shape, name: &str, layer: Layer) -> ChainExec {
    let mut net = Network::new("t");
    let i = net.add("data", Layer::Input { shape }, &[]);
    net.add(name, layer, &[i]);
    ChainExec::new(lower_network(&net, Mode::Inference)).strict()
}

fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length {} vs {}", got.len(), want.len());
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            (g - w).abs() <= tol,
            "{what}: element {i} differs: got {g}, want {w} (tol {tol})"
        );
    }
}

#[test]
fn conv_golden_2x2_kernels() {
    // 1×1×3×3 input, two 2×2 kernels, stride 1, no padding.
    let mut exec = single_layer(
        Shape::bchw(1, 1, 3, 3),
        "conv1",
        Layer::Conv { out_channels: 2, kernel: (2, 2), stride: 1, pad: 0, groups: 1 },
    );
    #[rustfmt::skip]
    let x = vec![
        1.0, 0.0, 2.0,
        3.0, 1.0, 0.0,
        0.0, 4.0, 1.0,
    ];
    exec.set_input("data.data", Tensor::new(&[1, 1, 3, 3], x).unwrap());
    // w0 = [[1,2],[3,4]], w1 = [[-1,1],[1,-1]] (OIHW).
    let w = vec![1.0, 2.0, 3.0, 4.0, -1.0, 1.0, 1.0, -1.0];
    exec.set_weights("conv1", Tensor::new(&[2, 1, 2, 2], w).unwrap());
    let out = exec.run_last().unwrap().outputs.remove(0);
    assert_eq!(out.dims(), &[1, 2, 2, 2]);
    #[rustfmt::skip]
    let want = vec![
        14.0, 7.0, 21.0, 17.0, // channel 0
        1.0, 3.0, -6.0, 2.0,   // channel 1
    ];
    assert_close(out.data(), &want, 1e-6, "conv");
}

#[test]
fn conv_golden_zero_padding() {
    // 3×3 all-ones kernel, pad 1 on a 2×2 input: every output window
    // covers the whole input, so all four outputs equal the input sum.
    let mut exec = single_layer(
        Shape::bchw(1, 1, 2, 2),
        "conv1",
        Layer::Conv { out_channels: 1, kernel: (3, 3), stride: 1, pad: 1, groups: 1 },
    );
    exec.set_input("data.data", Tensor::new(&[1, 1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap());
    exec.set_weights("conv1", Tensor::filled(&[1, 1, 3, 3], 1.0));
    let out = exec.run_last().unwrap().outputs.remove(0);
    assert_close(out.data(), &[10.0; 4], 1e-6, "padded conv");
}

#[test]
fn depthwise_conv_keeps_channels_isolated() {
    // groups == channels: each channel sees only its own kernel.
    let mut exec = single_layer(
        Shape::bchw(1, 2, 2, 2),
        "dw",
        Layer::Conv { out_channels: 2, kernel: (1, 1), stride: 1, pad: 0, groups: 2 },
    );
    exec.set_input(
        "data.data",
        Tensor::new(&[1, 2, 2, 2], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]).unwrap(),
    );
    exec.set_weights("dw", Tensor::new(&[2, 1, 1, 1], vec![10.0, -1.0]).unwrap());
    let out = exec.run_last().unwrap().outputs.remove(0);
    let want = vec![10.0, 20.0, 30.0, 40.0, -5.0, -6.0, -7.0, -8.0];
    assert_close(out.data(), &want, 1e-6, "depthwise conv");
}

#[test]
fn maxpool_golden() {
    let mut exec = single_layer(
        Shape::bchw(1, 1, 4, 4),
        "pool1",
        Layer::Pool { kind: PoolKind::Max, kernel: 2, stride: 2, pad: 0 },
    );
    let x: Vec<f32> = (1..=16).map(|v| v as f32).collect();
    exec.set_input("data.data", Tensor::new(&[1, 1, 4, 4], x).unwrap());
    let out = exec.run_last().unwrap().outputs.remove(0);
    assert_eq!(out.dims(), &[1, 1, 2, 2]);
    assert_close(out.data(), &[6.0, 8.0, 14.0, 16.0], 1e-6, "max pool");
}

#[test]
fn avgpool_golden() {
    let mut exec = single_layer(
        Shape::bchw(1, 1, 4, 4),
        "pool1",
        Layer::Pool { kind: PoolKind::Avg, kernel: 2, stride: 2, pad: 0 },
    );
    let x: Vec<f32> = (1..=16).map(|v| v as f32).collect();
    exec.set_input("data.data", Tensor::new(&[1, 1, 4, 4], x).unwrap());
    let out = exec.run_last().unwrap().outputs.remove(0);
    assert_close(out.data(), &[3.5, 5.5, 11.5, 13.5], 1e-6, "avg pool");
}

#[test]
fn global_avg_pool_golden() {
    let mut exec = single_layer(Shape::bchw(1, 2, 2, 2), "gap", Layer::GlobalAvgPool);
    exec.set_input(
        "data.data",
        Tensor::new(&[1, 2, 2, 2], vec![1.0, 2.0, 3.0, 4.0, 10.0, 20.0, 30.0, 40.0]).unwrap(),
    );
    let out = exec.run_last().unwrap().outputs.remove(0);
    assert_close(out.data(), &[2.5, 25.0], 1e-5, "global avg pool");
}

#[test]
fn batchnorm_golden() {
    // Batch 2, 2 channels: Table 2 FP1–FP4 with the native
    // rsqrt_eps LUT (1/√(Σ t1² + ε); see exec::interp docs).
    let mut exec = single_layer(Shape::bchw(2, 2, 1, 1), "bn1", Layer::BatchNorm);
    // x[b][c]: b0 = [1, -2], b1 = [3, 4].
    exec.set_input("data.data", Tensor::new(&[2, 2], vec![1.0, -2.0, 3.0, 4.0]).unwrap());
    let out = exec.run_last().unwrap().outputs.remove(0);
    // Per channel: μ = [2, 1], t1 = [[-1,-3],[1,3]], Σt1² = [2, 18].
    let rsqrt = |x| lut_apply("rsqrt_eps", x).unwrap();
    let t2 = [rsqrt(2.0), rsqrt(18.0)];
    let want = vec![-1.0 * t2[0], -3.0 * t2[1], 1.0 * t2[0], 3.0 * t2[1]];
    assert_close(out.data(), &want, 1e-6, "batch norm");
}

#[test]
fn relu_golden() {
    let mut exec = single_layer(Shape::bchw(1, 4, 1, 1), "relu1", Layer::Relu);
    exec.set_input("data.data", Tensor::new(&[4], vec![-1.0, 0.5, -0.25, 2.0]).unwrap());
    let out = exec.run_last().unwrap().outputs.remove(0);
    assert_close(out.data(), &[0.0, 0.5, 0.0, 2.0], 1e-7, "relu");
}

#[test]
fn fully_connected_golden() {
    let mut exec = single_layer(
        Shape::bchw(1, 4, 1, 1),
        "fc",
        Layer::FullyConnected { out_features: 3 },
    );
    exec.set_input("data.data", Tensor::new(&[4], vec![1.0, 2.0, 3.0, 4.0]).unwrap());
    #[rustfmt::skip]
    let w = vec![
        1.0, 0.0, 0.0, 0.0,
        0.0, 1.0, 0.0, -1.0,
        0.5, 0.5, 0.5, 0.5,
    ];
    exec.set_weights("fc", Tensor::new(&[3, 4], w).unwrap());
    let out = exec.run_last().unwrap().outputs.remove(0);
    assert_close(out.data(), &[1.0, -2.0, 5.0], 1e-6, "fully connected");
}

#[test]
fn softmax_golden() {
    // Softmax over channels, batch 2 (4-GCONV chain: max, sub+exp,
    // sum+recip, normalize).
    let mut exec = single_layer(Shape::bchw(2, 3, 1, 1), "sm", Layer::Softmax);
    exec.set_input("data.data", Tensor::new(&[2, 3], vec![1.0, 2.0, 3.0, 0.0, 0.0, 0.0]).unwrap());
    let out = exec.run_last().unwrap().outputs.remove(0);
    let e = [(-2.0f32).exp(), (-1.0f32).exp(), 1.0f32];
    let z: f32 = e.iter().sum();
    let want = vec![e[0] / z, e[1] / z, e[2] / z, 1.0 / 3.0, 1.0 / 3.0, 1.0 / 3.0];
    assert_close(out.data(), &want, 1e-5, "softmax");
}

/// Naive direct (grouped) convolution with zero padding, OIHW weights.
#[allow(clippy::too_many_arguments)]
fn naive_conv(
    x: &[f32],
    w: &[f32],
    b: usize,
    ic: usize,
    oc: usize,
    h: usize,
    wd: usize,
    k: usize,
    s: usize,
    p: usize,
    g: usize,
) -> Vec<f32> {
    let oh = (h + 2 * p - k) / s + 1;
    let ow = (wd + 2 * p - k) / s + 1;
    let icg = ic / g;
    let ocg = oc / g;
    let mut out = vec![0.0f32; b * oc * oh * ow];
    for bi in 0..b {
        for o in 0..oc {
            let go = o / ocg;
            for y in 0..oh {
                for xo in 0..ow {
                    let mut acc = 0.0f64;
                    for c in 0..icg {
                        for kh in 0..k {
                            for kw in 0..k {
                                let iy = (y * s + kh) as i64 - p as i64;
                                let ix = (xo * s + kw) as i64 - p as i64;
                                if iy < 0 || iy >= h as i64 || ix < 0 || ix >= wd as i64 {
                                    continue;
                                }
                                let xi = ((bi * ic + go * icg + c) * h + iy as usize) * wd
                                    + ix as usize;
                                let wi = ((o * icg + c) * k + kh) * k + kw;
                                acc += (x[xi] * w[wi]) as f64;
                            }
                        }
                    }
                    out[((bi * oc + o) * oh + y) * ow + xo] = acc as f32;
                }
            }
        }
    }
    out
}

#[test]
fn lowered_conv_matches_naive_reference() {
    // Property: for random small conv configurations, the lowered FP
    // conv GCONV evaluated natively matches direct convolution ≤ 1e-4.
    prop_check(40, |rng: &mut Rng| {
        let b = rng.int(1, 2);
        let k = rng.int(1, 3);
        let s = rng.int(1, 2);
        let p = rng.int(0, k / 2);
        let h = rng.int(k, 6);
        let wd = h; // square inputs (the IR lowers square windows)
        let depthwise = rng.bool(0.3);
        let (ic, oc, g) = if depthwise {
            let c = rng.int(1, 4);
            (c, c, c)
        } else {
            (rng.int(1, 3), rng.int(1, 4), 1)
        };

        let mut net = Network::new("prop");
        let i = net.add("data", Layer::Input { shape: Shape::bchw(b, ic, h, wd) }, &[]);
        net.add(
            "conv",
            Layer::Conv { out_channels: oc, kernel: (k, k), stride: s, pad: p, groups: g },
            &[i],
        );
        let chain = lower_network(&net, Mode::Inference);

        let x = Tensor::rand(&[b, ic, h, wd], rng.next_u64(), 1.0);
        let w = Tensor::rand(&[oc, ic / g, k, k], rng.next_u64(), 1.0);
        let want = naive_conv(x.data(), w.data(), b, ic, oc, h, wd, k, s, p, g);

        let mut exec = ChainExec::new(chain).strict();
        exec.set_input("data.data", x);
        exec.set_weights("conv", w);
        let got = exec
            .run_last()
            .map_err(|e| format!("b{b} ic{ic} oc{oc} h{h} k{k} s{s} p{p} g{g}: {e:#}"))?
            .outputs
            .remove(0);
        if got.elements() != want.len() {
            return Err(format!(
                "b{b} ic{ic} oc{oc} h{h} k{k} s{s} p{p} g{g}: {} outputs, want {}",
                got.elements(),
                want.len()
            ));
        }
        for (i, (a, e)) in got.data().iter().zip(&want).enumerate() {
            if (a - e).abs() > 1e-4 {
                return Err(format!(
                    "b{b} ic{ic} oc{oc} h{h} k{k} s{s} p{p} g{g}: element {i}: {a} vs {e}"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn mobilenet_block_inference_end_to_end() {
    // Full dw→BN→ReLU→pw→BN→ReLU block with synthesized weights: the
    // chain must execute, produce the right output volume, and (ending
    // in ReLU) be finite and non-negative.
    let chain = lower_network(&mobilenet_block(2, 4, 6), Mode::Inference);
    let mut exec = ChainExec::new(chain);
    exec.set_input("data.data", Tensor::rand(&[2, 4, 6, 6], 11, 1.0));
    let report = exec.run_last().unwrap();
    let out = &report.outputs[0];
    assert_eq!(out.elements(), 2 * 8 * 6 * 6);
    assert!(out.data().iter().all(|v| v.is_finite() && *v >= 0.0));
    assert_eq!(report.entries.len(), exec.chain().len());
    assert!(report.total_work() > 0);
}

#[test]
fn mobilenet_block_training_chain_executes() {
    // FP + BP + WG of the block (conv/BN/ReLU backward forms) runs
    // natively; every retained gradient is finite.
    let chain = lower_network(&mobilenet_block(2, 4, 6), Mode::Training);
    let n = chain.len();
    let wanted: Vec<usize> = (0..n).collect();
    let mut exec = ChainExec::new(chain);
    exec.set_input("data.data", Tensor::rand(&[2, 4, 6, 6], 13, 1.0));
    let report = exec.run(&wanted).unwrap();
    for (i, t) in report.outputs.iter().enumerate() {
        assert!(
            t.data().iter().all(|v| v.is_finite()),
            "entry #{i} produced a non-finite value"
        );
    }
}

/// Generate a random multi-dimensional GCONV with its bound tensors:
/// random groups, parallel kernels, window/stride/padding geometry,
/// operator combination, plus stride-tail slack and rank-aligned
/// broadcast operands — the full surface `Plan::bind` accepts.
fn random_gconv(rng: &mut Rng) -> (GconvOp, Tensor, Option<Tensor>) {
    let nd = rng.int(1, 3);
    let dim_names = [Dim::C, Dim::H, Dim::W];
    let mut dims = Vec::new();
    for &d in dim_names.iter().take(nd) {
        let ng = if rng.bool(0.25) { rng.int(2, 3) } else { 1 };
        let nop = if rng.bool(0.35) { rng.int(2, 4) } else { 1 };
        let nopc = rng.int(1, 5);
        let nks = rng.int(1, 3);
        let s = rng.int(1, 2);
        let ps = if nks > 1 && rng.bool(0.4) { rng.int(1, nks - 1) } else { 0 };
        dims.push((d, DimParams { ng, nop, nopc, nks, s, ps, ..Default::default() }));
    }

    // Half the cases are steered onto the GEMM tier: Mul+Add with a
    // reduction deep enough to clear GEMM_MIN_REDUCTION.
    let force_gemm = rng.bool(0.5);
    if force_gemm {
        let i = rng.int(0, nd - 1);
        dims[i].1.nks = rng.int(GEMM_MIN_REDUCTION, GEMM_MIN_REDUCTION + 4);
    }
    let main = if force_gemm {
        MainOp::Mul
    } else {
        *rng.choose(&[
            MainOp::Mul,
            MainOp::Add,
            MainOp::Sub,
            MainOp::SquareDiff,
            MainOp::Max,
            MainOp::And,
            MainOp::Pass,
        ])
    };
    let red_total: usize = dims.iter().map(|&(_, p)| p.nks).product();
    let reduce = if force_gemm {
        ReduceOp::Add
    } else if red_total == 1 && rng.bool(0.4) {
        ReduceOp::None
    } else {
        *rng.choose(&[ReduceOp::Add, ReduceOp::Max])
    };
    let pre = *rng.choose(&[
        PreOp::None,
        PreOp::None,
        PreOp::Square,
        PreOp::Mul(0.5),
        PreOp::Lut("relu"),
        PreOp::Lut("sigmoid"),
    ]);
    let post = *rng.choose(&[
        PostOp::None,
        PostOp::None,
        PostOp::Mul(2.0),
        PostOp::Lut("relu"),
        PostOp::Lut("sigmoid"),
        PostOp::Lut("exp"),
    ]);

    // Rank-aligned input: exact covered extents, stride-tail slack, or
    // an extent-1 broadcast dimension.
    let mut in_dims = Vec::new();
    for &(_, p) in &dims {
        let gi = p.input_extent() / p.ng;
        let exp = p.ng * gi;
        if exp > 1 && rng.bool(0.15) {
            in_dims.push(1);
        } else if p.nopc > 1 && rng.bool(0.3) {
            in_dims.push(p.ng * (gi + rng.int(1, 2)));
        } else {
            in_dims.push(exp);
        }
    }

    let needs_kernel = main != MainOp::Pass;
    let op = GconvOp {
        name: "prop".into(),
        dims,
        pre,
        main,
        reduce,
        post,
        input: DataRef::External("x".into()),
        kernel: if needs_kernel { Some(DataRef::Weights("w".into())) } else { None },
    };
    let x = Tensor::rand(&in_dims, rng.next_u64(), 1.0);
    let w = if needs_kernel {
        Some(Tensor::rand(&op.kernel_extents(), rng.next_u64(), 1.0))
    } else {
        None
    };
    (op, x, w)
}

#[test]
fn fast_paths_match_naive_oracle_bitwise() {
    // Property: whatever tier `eval_gconv` dispatches to produces the
    // *same bits* as the naive per-element oracle — same f32 operator
    // applications, same f64 accumulation order.
    prop_check(150, |rng| {
        let (op, x, w) = loop {
            let cand = random_gconv(rng);
            if cand.0.work() <= 200_000 {
                break cand;
            }
        };
        let fast = eval_gconv(&op, &x, w.as_ref())
            .map_err(|e| format!("fast: {op} over {:?}: {e:#}", x.dims()))?;
        let naive = eval_gconv_naive(&op, &x, w.as_ref())
            .map_err(|e| format!("naive: {op} over {:?}: {e:#}", x.dims()))?;
        if !fast.bit_eq(&naive) {
            let tier = plan_tier(&op, &x, w.as_ref()).unwrap();
            return Err(format!(
                "{op} (tier {tier:?}) over {:?}: max |Δ| = {:e}",
                x.dims(),
                fast.max_abs_diff(&naive)
            ));
        }
        Ok(())
    });
}

#[test]
fn fast_precision_matches_bitexact_within_tolerance() {
    // Property: `Precision::Fast` (the lane-parallel GEMM microkernel)
    // stays within FAST_REL_TOL of the bit-exact path on every
    // randomized shape. Only the GEMM tier reacts to the knob, so on
    // every other tier Fast must stay bit-identical.
    prop_check(150, |rng| {
        let (op, x, w) = loop {
            let cand = random_gconv(rng);
            if cand.0.work() <= 200_000 {
                break cand;
            }
        };
        let exact = eval_gconv(&op, &x, w.as_ref())
            .map_err(|e| format!("bitexact: {op} over {:?}: {e:#}", x.dims()))?;
        let fast = eval_gconv_with_precision(&op, &x, w.as_ref(), Precision::Fast)
            .map_err(|e| format!("fast: {op} over {:?}: {e:#}", x.dims()))?;
        let tier = plan_tier(&op, &x, w.as_ref()).unwrap();
        if tier != KernelTier::Gemm && !fast.bit_eq(&exact) {
            return Err(format!(
                "{op} (tier {tier:?}) over {:?}: Precision::Fast changed a non-GEMM tier",
                x.dims()
            ));
        }
        let tol = f64::from(FAST_REL_TOL);
        for (i, (a, b)) in fast.data().iter().zip(exact.data()).enumerate() {
            let rel = f64::from((a - b).abs()) / f64::from(b.abs()).max(1.0);
            if rel > tol {
                return Err(format!(
                    "{op} (tier {tier:?}) over {:?}: element {i} rel err {rel:e} > {tol:e}",
                    x.dims()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn lowered_conv_takes_the_gemm_tier() {
    // A real lowered convolution (ic·kh·kw = 27 reduction steps) must
    // dispatch onto the dense dot/GEMM fast path.
    let mut net = Network::new("t");
    let i = net.add("data", Layer::Input { shape: Shape::bchw(1, 3, 8, 8) }, &[]);
    net.add(
        "conv",
        Layer::Conv { out_channels: 4, kernel: (3, 3), stride: 1, pad: 1, groups: 1 },
        &[i],
    );
    let chain = lower_network(&net, Mode::Inference);
    let e = &chain.entries()[chain.len() - 1];
    let x = Tensor::rand(&e.op.input_extents(), 3, 1.0);
    let w = Tensor::rand(&e.op.kernel_extents(), 4, 1.0);
    assert_eq!(plan_tier(&e.op, &x, Some(&w)).unwrap(), KernelTier::Gemm);
}

#[test]
fn training_chain_fast_vs_naive_bitwise() {
    // The full FP+BP+WG chain of a MobileNet block exercises every
    // lowered op form (conv/BN/ReLU forward and backward); the fast
    // tiers must match the oracle on every retained entry.
    let net = mobilenet_block(2, 4, 6);
    let chain = lower_network(&net, Mode::Training);
    let wanted: Vec<usize> = (0..chain.len()).collect();
    let mut fast = ChainExec::new(chain);
    let naive_chain = lower_network(&net, Mode::Training);
    let mut naive = ChainExec::new(naive_chain).with_naive_oracle();
    let x = Tensor::rand(&[2, 4, 6, 6], 17, 1.0);
    fast.set_input("data.data", x.clone());
    naive.set_input("data.data", x);
    let rf = fast.run(&wanted).unwrap();
    let rn = naive.run(&wanted).unwrap();
    assert_eq!(rf.outputs.len(), rn.outputs.len());
    for (i, (a, b)) in rf.outputs.iter().zip(&rn.outputs).enumerate() {
        assert!(a.bit_eq(b), "entry #{i} diverged from the oracle");
    }
}

/// Build a random chain of one arbitrary host op followed by a run of
/// element-wise followers (every fusible `pre`/`main`/`reduce`/`post`
/// combination: scalar LUTs, scales, squares, pure copies) and an
/// optional padded windowed consumer, plus an optional second reader of
/// the host (which forces the consumer-fusion path instead of
/// producer fusion). Exercises compose-into-post, compose-into-pre,
/// elision and the refuse paths of `fuse_executable`.
fn random_fusible_chain(rng: &mut Rng) -> GconvChain {
    let mut chain = GconvChain::new("fuseprop");
    let push = |chain: &mut GconvChain, op: GconvOp| -> usize {
        chain.push(ChainEntry::new(op, 0, true, Phase::Fp))
    };

    // Host op: a couple of dims with modest extents, random operators.
    let nd = rng.int(1, 2);
    let dim_names = [Dim::C, Dim::W];
    let mut dims = Vec::new();
    for &d in dim_names.iter().take(nd) {
        let ng = if rng.bool(0.25) { rng.int(2, 3) } else { 1 };
        let nop = if rng.bool(0.3) { rng.int(2, 3) } else { 1 };
        let nopc = rng.int(1, 4);
        let nks = rng.int(1, 3);
        let ps = if nks > 1 && rng.bool(0.3) { 1 } else { 0 };
        dims.push((d, DimParams { ng, nop, nopc, nks, s: 1, ps, ..Default::default() }));
    }
    let red: usize = dims.iter().map(|&(_, p)| p.nks).product();
    let host = GconvOp {
        name: "host".into(),
        dims,
        pre: *rng.choose(&[PreOp::None, PreOp::Square, PreOp::Mul(0.5)]),
        main: *rng.choose(&[MainOp::Mul, MainOp::Add, MainOp::Max]),
        reduce: if red == 1 { ReduceOp::None } else { *rng.choose(&[ReduceOp::Add, ReduceOp::Max]) },
        post: *rng.choose(&[PostOp::None, PostOp::Mul(2.0), PostOp::Lut("sigmoid")]),
        input: DataRef::External("x".into()),
        kernel: Some(DataRef::Weights("w".into())),
    };
    let out_dims: Vec<(Dim, usize)> = host
        .dims
        .iter()
        .zip(host.output_extents())
        .map(|(&(d, _), e)| (d, e))
        .collect();
    let mut last = push(&mut chain, host);

    // Optional second reader of the host blocks producer fusion of the
    // first follower, steering it onto the consumer-fusion path.
    if rng.bool(0.3) {
        let spy = GconvOp {
            name: "spy".into(),
            dims: out_dims.iter().map(|&(d, e)| (d, DimParams::opc(e))).collect(),
            pre: PreOp::None,
            main: MainOp::Pass,
            reduce: ReduceOp::None,
            post: PostOp::Lut("exp"),
            input: DataRef::Gconv(0),
            kernel: None,
        };
        push(&mut chain, spy);
    }

    // Element-wise followers.
    for fi in 0..rng.int(1, 3) {
        let follower = GconvOp {
            name: format!("f{fi}"),
            dims: out_dims
                .iter()
                .map(|&(d, e)| {
                    if rng.bool(0.5) {
                        (d, DimParams::g(e))
                    } else {
                        (d, DimParams::opc(e))
                    }
                })
                .collect(),
            pre: *rng.choose(&[PreOp::None, PreOp::None, PreOp::Square, PreOp::Lut("relu")]),
            main: MainOp::Pass,
            reduce: ReduceOp::None,
            post: *rng.choose(&[
                PostOp::None,
                PostOp::None,
                PostOp::Mul(2.0),
                PostOp::Lut("relu"),
                PostOp::Lut("sigmoid"),
            ]),
            input: DataRef::Gconv(last),
            kernel: None,
        };
        last = push(&mut chain, follower);
    }

    // Optional windowed consumer (padded half the time): composes the
    // final follower into its pre when the padding rules allow.
    if rng.bool(0.7) {
        if let Some(&(d, e)) = out_dims.iter().find(|&&(_, e)| e >= 2) {
            let nks = rng.int(1, 2.min(e));
            let ps = if nks > 1 && rng.bool(0.5) { 1 } else { 0 };
            let nopc = e + 2 * ps - nks + 1;
            let mut dims = vec![(d, DimParams::window(nopc, nks, 1, ps))];
            for &(d2, e2) in &out_dims {
                if d2 != d {
                    dims.push((d2, DimParams::opc(e2)));
                }
            }
            dims.sort_by_key(|&(d, _)| out_dims.iter().position(|&(x, _)| x == d));
            let consumer = GconvOp {
                name: "sink".into(),
                dims,
                pre: *rng.choose(&[PreOp::None, PreOp::Mul(0.5)]),
                main: MainOp::Mul,
                reduce: ReduceOp::Add,
                post: PostOp::None,
                input: DataRef::Gconv(last),
                kernel: Some(DataRef::Weights("wc".into())),
            };
            push(&mut chain, consumer);
        }
    }
    chain
}

#[test]
fn fused_chains_match_the_unfused_naive_oracle_bitwise() {
    // Property: `fuse_executable` preserves the final output bit-for-bit
    // against the *unfused chain on the naive oracle*, across random
    // fusible op combinations (compose-into-post, compose-into-pre,
    // elision, stack overflow refusal, padded-consumer zero rules).
    prop_check(120, |rng: &mut Rng| {
        let unfused = random_fusible_chain(rng);
        let mut fused = unfused.clone();
        let stats = fuse_executable(&mut fused);
        if stats.after > stats.before {
            return Err("fusion grew the chain".into());
        }
        let x_dims: Vec<usize> = unfused.entries()[0].op.input_extents();
        let x = Tensor::rand(&x_dims, rng.next_u64(), 1.0);
        let mut slow = ChainExec::new(unfused).with_naive_oracle();
        slow.set_input("x", x.clone());
        let mut fast = ChainExec::new(fused);
        fast.set_input("x", x);
        let a = slow.run_last().map_err(|e| format!("unfused: {e:#}"))?;
        let b = fast.run_last().map_err(|e| format!("fused: {e:#}"))?;
        if !a.outputs[0].bit_eq(&b.outputs[0]) {
            return Err(format!(
                "fused output diverged (chain {} → {}): max |Δ| = {:e}",
                stats.before,
                stats.after,
                a.outputs[0].max_abs_diff(&b.outputs[0])
            ));
        }
        Ok(())
    });
}

#[test]
fn maxpool_bp_routes_gradient_to_the_window_winner() {
    // Single max-pool layer, training mode: the BP entry recomputes the
    // argmax from the forward input and routes the loss gradient there.
    let mut net = Network::new("t");
    let i = net.add("data", Layer::Input { shape: Shape::bchw(1, 1, 2, 2) }, &[]);
    net.add("pool", Layer::Pool { kind: PoolKind::Max, kernel: 2, stride: 2, pad: 0 }, &[i]);
    let chain = lower_network(&net, Mode::Training);
    let bp = chain
        .entries()
        .iter()
        .position(|e| e.special.is_some())
        .expect("training chain must carry the argmax-routing special");
    let mut exec = ChainExec::new(chain).strict();
    exec.set_input("data.data", Tensor::new(&[1, 1, 2, 2], vec![1.0, 3.0, 2.0, 4.0]).unwrap());
    exec.set_input("loss_grad.1", Tensor::new(&[1, 1, 1, 1], vec![10.0]).unwrap());
    let out = exec.run(&[bp]).unwrap().outputs.remove(0);
    assert_eq!(out.data(), &[0.0, 0.0, 0.0, 10.0]);
}

#[test]
fn mobilenet_training_chain_with_maxpool_executes_end_to_end() {
    // A MobileNet-style block with a ceil-mode max pool between the
    // depthwise and pointwise stages: the full FP+BP+WG chain must run
    // natively (the pool BP routes through the recomputed argmax) and
    // every retained tensor must be finite.
    let mut net = Network::new("MobileNetPoolBlock");
    let input = net.add("data", Layer::Input { shape: Shape::bchw(2, 4, 8, 8) }, &[]);
    let dw = net.add(
        "conv_dw",
        Layer::Conv { out_channels: 4, kernel: (3, 3), stride: 1, pad: 1, groups: 4 },
        &[input],
    );
    let bn1 = net.add("bn_dw", Layer::BatchNorm, &[dw]);
    let r1 = net.add("relu_dw", Layer::Relu, &[bn1]);
    // 3x3 stride-2 pad-1 over 8 → ceil-mode output 5 (last window clips).
    let pool =
        net.add("pool", Layer::Pool { kind: PoolKind::Max, kernel: 3, stride: 2, pad: 1 }, &[r1]);
    let pw = net.add(
        "conv_pw",
        Layer::Conv { out_channels: 8, kernel: (1, 1), stride: 1, pad: 0, groups: 1 },
        &[pool],
    );
    let bn2 = net.add("bn_pw", Layer::BatchNorm, &[pw]);
    net.add("relu_pw", Layer::Relu, &[bn2]);

    let chain = lower_network(&net, Mode::Training);
    assert!(
        chain.entries().iter().any(|e| e.special.is_some()),
        "training chain must carry the max-pool BP special"
    );
    let n = chain.len();
    let wanted: Vec<usize> = (0..n).collect();
    let mut exec = ChainExec::new(chain);
    exec.set_input("data.data", Tensor::rand(&[2, 4, 8, 8], 23, 1.0));
    let report = exec.run(&wanted).unwrap();
    assert_eq!(report.entries.len(), n);
    for (i, t) in report.outputs.iter().enumerate() {
        assert!(
            t.data().iter().all(|v| v.is_finite()),
            "entry #{i} produced a non-finite value"
        );
    }
}

#[test]
fn ceil_mode_pool_clips_overhanging_windows() {
    // 2x2 stride-2 pool over 5x5 (Caffe rounds the output up to 3x3):
    // the edge windows clip to the input instead of failing to bind.
    let mut exec = single_layer(
        Shape::bchw(1, 1, 5, 5),
        "pool1",
        Layer::Pool { kind: PoolKind::Max, kernel: 2, stride: 2, pad: 0 },
    );
    let x: Vec<f32> = (1..=25).map(|v| v as f32).collect();
    exec.set_input("data.data", Tensor::new(&[1, 1, 5, 5], x).unwrap());
    let out = exec.run_last().unwrap().outputs.remove(0);
    assert_eq!(out.dims(), &[1, 1, 3, 3]);
    let want = vec![7.0, 9.0, 10.0, 17.0, 19.0, 20.0, 22.0, 24.0, 25.0];
    assert_close(out.data(), &want, 1e-6, "ceil-mode max pool");
}

#[test]
fn concat_chain_stacks_branches_along_channels() {
    // concat([x, relu(x)]) over C: the special concat entry produces
    // the two blocks side by side.
    let mut net = Network::new("cat");
    let i = net.add("data", Layer::Input { shape: Shape::bchw(1, 2, 2, 2) }, &[]);
    let r = net.add("relu", Layer::Relu, &[i]);
    net.add("cat", Layer::Concat, &[i, r]);
    let chain = lower_network(&net, Mode::Inference);
    assert!(chain.entries().iter().any(|e| e.special.is_some()));
    let mut exec = ChainExec::new(chain).strict();
    let xs = vec![1.0, -1.0, 2.0, -2.0, 3.0, -3.0, 4.0, -4.0];
    exec.set_input("data.data", Tensor::new(&[1, 2, 2, 2], xs.clone()).unwrap());
    let out = exec.run_last().unwrap().outputs.remove(0);
    assert_eq!(out.elements(), 16);
    let mut want = xs.clone();
    want.extend(xs.iter().map(|v| v.max(0.0)));
    assert_close(out.data(), &want, 1e-7, "channel concat");
}

// NOTE: the former fused-vs-unfused benchmark smokes
// (`mobilenet_and_alexnet_fp_chains_run_fused_and_unfused` and the
// all-seven `--ignored` variant) moved into the cross-engine
// conformance matrix in `tests/conformance.rs`, which pins {naive,
// fast, fused, session-reuse} bit-identical in one table and checks
// the committed golden digests on top.

#[test]
fn small_cnn_softmax_distributions_sum_to_one() {
    // conv → ReLU → maxpool → FC → softmax, synthesized weights: each
    // sample's output must be a probability distribution.
    let mut net = Network::new("small");
    let i = net.add("data", Layer::Input { shape: Shape::bchw(2, 3, 8, 8) }, &[]);
    let c = net.add(
        "conv1",
        Layer::Conv { out_channels: 4, kernel: (3, 3), stride: 1, pad: 1, groups: 1 },
        &[i],
    );
    let r = net.add("relu1", Layer::Relu, &[c]);
    let pl = net.add("pool1", Layer::Pool { kind: PoolKind::Max, kernel: 2, stride: 2, pad: 0 }, &[r]);
    let f = net.add("fc", Layer::FullyConnected { out_features: 5 }, &[pl]);
    net.add("prob", Layer::Softmax, &[f]);

    let mut exec = ChainExec::new(lower_network(&net, Mode::Inference));
    exec.set_input("data.data", Tensor::rand(&[2, 3, 8, 8], 21, 1.0));
    let out = exec.run_last().unwrap().outputs.remove(0);
    assert_eq!(out.elements(), 2 * 5);
    for row in out.data().chunks(5) {
        let sum: f32 = row.iter().sum();
        assert!((sum - 1.0).abs() < 1e-4, "softmax row sums to {sum}");
        assert!(row.iter().all(|p| (0.0..=1.0).contains(p)));
    }
}
