//! Cross-engine conformance suite: one table-driven harness that runs
//! benchmark FP chains through every engine path — the naive
//! per-element oracle, the tiered fast paths, the executable-fused
//! chain, and bind-once/run-many session reuse — and pins all of them
//! **bit-identical**. This replaces the earlier scattered pairwise
//! checks (fast-vs-naive here, fused-vs-unfused there) with a single
//! matrix; any new engine path gets added to [`run_path`] and is
//! covered everywhere at once.
//!
//! On top of the matrix, tiny fixed-seed golden digests are kept under
//! `rust/tests/goldens/` (first-k output words + an FNV-1a hash of the
//! full output bit pattern). The matrix only proves the paths agree
//! *with each other*; the committed digest catches a silent semantic
//! change that moves every path at once — something no differential
//! test can see. A golden file without a digest (the committed
//! bootstrap state) is populated in place and reported, so the gate
//! arms as soon as a populated file is committed; with a digest
//! present the comparison is strict and `UPDATE_GOLDENS=1` is the only
//! way to move it.
//!
//! The seven-network matrix needs the naive oracle on the heavy nets
//! and runs `#[ignore]`d in debug; CI executes it in release. The
//! tier-1 (debug) half covers the full four-path matrix on small
//! chains plus the three fast paths and golden digests of MN + AN.

use std::env;
use std::fs;
use std::path::PathBuf;

use gconv_chain::exec::bench::input_spec;
use gconv_chain::exec::serve::{Engine, Session};
use gconv_chain::exec::{ChainExec, Tensor};
use gconv_chain::gconv::chain::GconvChain;
use gconv_chain::gconv::lower::{lower_network, Mode};
use gconv_chain::ir::{Layer, Network, PoolKind, Shape};
use gconv_chain::mapping::fuse_executable;
use gconv_chain::networks::{benchmark_with_batch, mobilenet_block, BENCHMARK_CODES};
use gconv_chain::prop::prop_check;

/// Input seed of every conformance run (the golden digests pin the
/// outputs for exactly this seed, batch 1 and synthesized weights).
const INPUT_SEED: u64 = 0xC0F_FEE5;

/// One engine path of the matrix.
#[derive(Clone, Copy, Debug, PartialEq)]
enum Path {
    /// `ChainExec` forced onto the per-element oracle.
    Naive,
    /// `ChainExec` on the tiered fast paths.
    Fast,
    /// `ChainExec` on the executable-fused chain.
    Fused,
    /// A `Session` run twice — the *second* (buffer-recycling,
    /// zero-bind) run is the compared output, so the matrix also pins
    /// that reuse never drifts.
    Session,
}

const ALL_PATHS: [Path; 4] = [Path::Naive, Path::Fast, Path::Fused, Path::Session];
const FAST_PATHS: [Path; 3] = [Path::Fast, Path::Fused, Path::Session];

/// Run one network's FP chain through `path` and return the final
/// output tensor.
fn run_path(net: &Network, path: Path) -> Tensor {
    let (input_name, dims) = input_spec(net).unwrap();
    let x = Tensor::rand(&dims, INPUT_SEED, 1.0);
    let mut chain = lower_network(net, Mode::Inference);
    if path == Path::Fused {
        fuse_executable(&mut chain);
    }
    match path {
        Path::Session => {
            let mut session = Session::builder(chain)
                .input(&input_name, x)
                .build()
                .unwrap_or_else(|e| panic!("{}: session build: {e:#}", net.name));
            let binds = session.stats().plan_binds;
            let first = session.run().unwrap_or_else(|e| panic!("{}: {e:#}", net.name));
            session.recycle(first);
            let mut second = session.run().unwrap_or_else(|e| panic!("{}: {e:#}", net.name));
            assert_eq!(
                session.stats().plan_binds,
                binds,
                "{}: session reuse must not rebind plans",
                net.name
            );
            (*second.outputs.remove(0)).clone()
        }
        _ => {
            let mut exec = ChainExec::new(chain);
            if path == Path::Naive {
                exec = exec.with_naive_oracle();
            }
            exec.set_input(&input_name, x);
            let mut report =
                exec.run_last().unwrap_or_else(|e| panic!("{}: {e:#}", net.name));
            (*report.outputs.remove(0)).clone()
        }
    }
}

/// Run the matrix row for `net`: every path's final output must match
/// the first path's bit-for-bit, and executable fusion must actually
/// shorten the chain (otherwise the Fused leg degenerates into a
/// trivial unfused-vs-unfused comparison). Returns the reference
/// output.
fn assert_matrix(net: &Network, paths: &[Path]) -> Tensor {
    let unfused_len = lower_network(net, Mode::Inference).len();
    let mut fused_chain = lower_network(net, Mode::Inference);
    fuse_executable(&mut fused_chain);
    assert!(
        fused_chain.len() < unfused_len,
        "{}: executable fusion did not shorten the chain ({unfused_len} -> {})",
        net.name,
        fused_chain.len()
    );
    let reference = run_path(net, paths[0]);
    for &path in &paths[1..] {
        let out = run_path(net, path);
        assert!(
            reference.bit_eq(&out),
            "{}: engine path {path:?} diverged bitwise from {:?} (max |Δ| = {:e})",
            net.name,
            paths[0],
            reference.max_abs_diff(&out)
        );
    }
    reference
}

/// FNV-1a over a byte string.
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Render the digest document for one output tensor.
fn render_golden(code: &str, out: &Tensor) -> String {
    let mut bytes = Vec::with_capacity(out.elements() * 4);
    for v in out.data() {
        bytes.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    let head: Vec<String> =
        out.data().iter().take(8).map(|v| format!("{:08x}", v.to_bits())).collect();
    format!(
        "# gconv golden digest v1 — {code} inference chain, batch 1, input seed \
         {INPUT_SEED:#x}, synthesized weights (default seed), fast-path output.\n\
         # All engine paths are pinned bit-identical to this digest by \
         tests/conformance.rs.\n\
         # Regenerate (semantic changes only): UPDATE_GOLDENS=1 cargo test --release \
         --test conformance -- --ignored\n\
         elements {}\nfnv64 {:016x}\nhead {}\n",
        out.elements(),
        fnv1a64(&bytes),
        head.join(" ")
    )
}

/// Compare `out` against the committed digest of `code`, or populate a
/// digest-less (bootstrap-state) golden file in place.
fn check_golden(code: &str, out: &Tensor) {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/goldens");
    let path = dir.join(format!("{code}.golden"));
    let current = render_golden(code, out);
    let committed = fs::read_to_string(&path).unwrap_or_default();
    let update = env::var_os("UPDATE_GOLDENS").is_some();
    let digest_only =
        |s: &str| s.lines().filter(|l| !l.starts_with('#')).collect::<Vec<_>>().join("\n");
    if committed.lines().any(|l| l.starts_with("fnv64 ")) && !update {
        assert_eq!(
            digest_only(&committed),
            digest_only(&current),
            "{code}: engine output drifted from the committed golden digest \
             (rust/tests/goldens/{code}.golden). Every engine path moved together — \
             this is a semantic change no differential test can see. If intended, \
             regenerate with UPDATE_GOLDENS=1 and commit the new digest."
        );
    } else {
        fs::create_dir_all(&dir).ok();
        fs::write(&path, &current)
            .unwrap_or_else(|e| panic!("{code}: cannot populate golden file: {e}"));
        eprintln!(
            "golden {code}: digest populated — commit rust/tests/goldens/{code}.golden \
             to arm the drift gate"
        );
    }
}

/// A small conv→ReLU→pool→FC→softmax classifier (per-sample ops only).
fn small_classifier(batch: usize) -> Network {
    let mut net = Network::new("small");
    let i = net.add("data", Layer::Input { shape: Shape::bchw(batch, 3, 8, 8) }, &[]);
    let c = net.add(
        "conv1",
        Layer::Conv { out_channels: 4, kernel: (3, 3), stride: 1, pad: 1, groups: 1 },
        &[i],
    );
    let r = net.add("relu1", Layer::Relu, &[c]);
    let p = net.add(
        "pool1",
        Layer::Pool { kind: PoolKind::Max, kernel: 2, stride: 2, pad: 0 },
        &[r],
    );
    let f = net.add("fc", Layer::FullyConnected { out_features: 5 }, &[p]);
    net.add("prob", Layer::Softmax, &[f]);
    net
}

#[test]
fn conformance_matrix_small_chains_all_four_paths() {
    // Full 4-path matrix (including the naive oracle) on chains cheap
    // enough for debug mode: a BN-bearing MobileNet block and a
    // conv/pool/FC/softmax classifier.
    assert_matrix(&mobilenet_block(2, 4, 6), &ALL_PATHS);
    assert_matrix(&small_classifier(2), &ALL_PATHS);
}

#[test]
fn conformance_matrix_mn_an_with_goldens() {
    // Tier-1 half of the benchmark matrix: MobileNet + AlexNet at
    // batch 1 through the three fast paths (the naive oracle on the
    // full nets runs in the release `--ignored` matrix below), plus
    // the committed golden digests.
    for code in ["MN", "AN"] {
        let net = benchmark_with_batch(code, 1);
        let reference = assert_matrix(&net, &FAST_PATHS);
        check_golden(code, &reference);
    }
}

#[test]
#[ignore = "naive oracle over the heavy nets takes minutes in debug; CI runs it in \
            release via `cargo test --release -- --ignored`"]
fn conformance_matrix_all_seven_networks_all_four_paths() {
    for code in BENCHMARK_CODES {
        let net = benchmark_with_batch(code, 1);
        let reference = assert_matrix(&net, &ALL_PATHS);
        check_golden(code, &reference);
    }
}

#[test]
fn engine_coalescing_is_invariant_over_batching() {
    // Property: N single-sample requests coalesced by the Engine into
    // one micro-batch produce bit-identical per-sample outputs to N
    // independent batch-1 Session runs, across randomized per-sample
    // networks (conv/ReLU/pool/FC — no batch statistics), shapes,
    // seeds and the fuse flag.
    prop_check(10, |rng| {
        let c = rng.int(1, 3);
        let hw = rng.int(4, 6);
        let oc = rng.int(1, 4);
        let k = rng.int(1, 3);
        let pad = rng.int(0, k - 1).min(1);
        let features = rng.int(2, 5);
        let with_pool = rng.bool(0.5);
        let fuse = rng.bool(0.5);
        let build = move |batch: usize| -> Network {
            let mut net = Network::new("prop-serve");
            let i = net.add("data", Layer::Input { shape: Shape::bchw(batch, c, hw, hw) }, &[]);
            let conv = net.add(
                "conv",
                Layer::Conv { out_channels: oc, kernel: (k, k), stride: 1, pad, groups: 1 },
                &[i],
            );
            let mut last = net.add("relu", Layer::Relu, &[conv]);
            if with_pool {
                last = net.add(
                    "pool",
                    Layer::Pool { kind: PoolKind::Max, kernel: 2, stride: 2, pad: 0 },
                    &[last],
                );
            }
            net.add("fc", Layer::FullyConnected { out_features: features }, &[last]);
            net
        };

        let n = rng.int(2, 4);
        let sample_len = c * hw * hw;
        let samples: Vec<Vec<f32>> = (0..n)
            .map(|_| Tensor::rand(&[sample_len], rng.next_u64(), 1.0).into_data())
            .collect();

        let mut engine = Engine::new(n).with_fuse(fuse);
        engine.register("prop", build);
        for (i, s) in samples.iter().enumerate() {
            engine.submit("prop", i as u64, s.clone()).map_err(|e| format!("submit: {e:#}"))?;
        }
        let mut responses = engine.drain().map_err(|e| format!("drain: {e:#}"))?;
        responses.sort_by_key(|r| r.id);
        if responses.len() != n {
            return Err(format!("{} responses for {n} requests", responses.len()));
        }
        if responses.iter().any(|r| r.batch != n) {
            return Err(format!(
                "per-sample net must coalesce into one batch of {n} (got sizes {:?})",
                responses.iter().map(|r| r.batch).collect::<Vec<_>>()
            ));
        }

        for (i, s) in samples.iter().enumerate() {
            let mut chain: GconvChain = lower_network(&build(1), Mode::Inference);
            if fuse {
                fuse_executable(&mut chain);
            }
            let mut session = Session::builder(chain)
                .input("data.data", Tensor::new(&[1, c, hw, hw], s.clone()).unwrap())
                .build()
                .map_err(|e| format!("session build: {e:#}"))?;
            let want = session.run().map_err(|e| format!("session run: {e:#}"))?;
            let wd = want.outputs[0].data();
            let got = &responses[i].data;
            if got.len() != wd.len() {
                return Err(format!("sample {i}: {} values, want {}", got.len(), wd.len()));
            }
            for (j, (a, b)) in got.iter().zip(wd).enumerate() {
                if a.to_bits() != b.to_bits() {
                    return Err(format!(
                        "c{c} hw{hw} oc{oc} k{k} pad{pad} pool{with_pool} fuse{fuse} n{n}: \
                         sample {i} element {j}: coalesced {a} vs batch-1 {b}"
                    ));
                }
            }
        }
        Ok(())
    });
}
