//! Chaos suite for the self-healing serving front.
//!
//! Drives concurrent TCP clients against a server whose fault-injection
//! registry (`gconv_chain::exec::faults`) is armed with a seeded plan,
//! and asserts the robustness contract end to end:
//!
//! * **No deadlock** — every request is answered within its socket
//!   timeout, even while waves panic, error, and stall.
//! * **Exactly one reply per accepted request** — accounting closes:
//!   `submitted == completed + errored + expired` on the health frame.
//! * **Bounded queue** — the high-water mark never exceeds the
//!   configured depth, faults or not.
//! * **Quarantine isolation** — a panicking model is refused with
//!   `QUARANTINED` while every other model keeps serving responses
//!   bit-identical to an in-process reference engine.
//! * **Numerics are sacred** — injection fails requests; it never
//!   corrupts a successful response.
//!
//! Arming is process-global, so the tests serialize on a local mutex
//! (the registry's own arm-lock would serialize the arming itself, but
//! the *disarmed* control test must not overlap an armed soak either).

use std::sync::Mutex;
use std::time::Duration;

use gconv_chain::exec::faults::{self, FaultKind, FaultPlan, FaultRule, Trigger};
use gconv_chain::exec::serve::Engine;
use gconv_chain::exec::Tensor;
use gconv_chain::ir::{Layer, Network, Shape};
use gconv_chain::server::{serve, Client, ErrorCode, Response, ServerConfig, ServerHandle};

/// Serializes the whole suite: the fault registry is process-global.
static SEQ: Mutex<()> = Mutex::new(());

const SAMPLE_DIMS: [usize; 3] = [2, 4, 4];
const SAMPLE_LEN: usize = 2 * 4 * 4;
const MODELS: [&str; 3] = ["good", "flaky", "bad"];

fn tiny_net(batch: usize) -> Network {
    let mut net = Network::new("tiny");
    let i = net.add("data", Layer::Input { shape: Shape::bchw(batch, 2, 4, 4) }, &[]);
    let c = net.add(
        "conv",
        Layer::Conv { out_channels: 3, kernel: (3, 3), stride: 1, pad: 1, groups: 1 },
        &[i],
    );
    let r = net.add("relu", Layer::Relu, &[c]);
    net.add("fc", Layer::FullyConnected { out_features: 5 }, &[r]);
    net
}

/// An engine with every chaos model registered (all share one builder,
/// so one reference output covers any model given the same input).
fn chaos_engine(max_batch: usize) -> Engine {
    let mut engine = Engine::new(max_batch);
    for code in MODELS {
        engine.register(code, tiny_net);
    }
    engine
}

fn sample(seed: u64) -> Vec<f32> {
    Tensor::rand(&[SAMPLE_LEN], seed, 1.0).into_data()
}

/// In-process reference for `(model, input)` pairs, keyed by request
/// index — the oracle every successful wire response is pinned to.
fn reference_outputs(traffic: &[(usize, &'static str, Vec<f32>)]) -> Vec<Vec<f32>> {
    let mut engine = chaos_engine(1);
    for (id, model, x) in traffic {
        engine.submit(model, *id as u64, x.clone()).unwrap();
    }
    let mut responses = engine.drain().unwrap();
    responses.sort_by_key(|r| r.id);
    assert_eq!(responses.len(), traffic.len(), "reference engine dropped requests");
    responses.into_iter().map(|r| r.data).collect()
}

fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

fn connect(handle: &ServerHandle) -> Client {
    let mut client = Client::connect_retry(&handle.addr().to_string(), Duration::from_secs(10))
        .expect("connect to the chaos server");
    // The no-deadlock bound: a swallowed reply fails the read loudly
    // instead of hanging the suite.
    client.set_timeouts(Duration::from_secs(30), Duration::from_secs(10)).expect("timeouts");
    client
}

fn rule(site: &str, scope: Option<&str>, kind: FaultKind, trigger: Trigger) -> FaultRule {
    FaultRule {
        site: site.to_string(),
        scope: scope.map(str::to_string),
        kind,
        trigger,
    }
}

/// What one wire exchange produced, for the accounting asserts.
#[derive(Debug, PartialEq, Eq, Clone, Copy)]
enum Outcome {
    Output,
    Internal,
    Quarantined,
    Busy,
}

fn classify(resp: Response) -> (Outcome, Option<Vec<f32>>) {
    match resp {
        Response::Output { data, .. } => (Outcome::Output, Some(data)),
        Response::Error { code: ErrorCode::Internal, .. } => (Outcome::Internal, None),
        Response::Error { code: ErrorCode::Quarantined, .. } => (Outcome::Quarantined, None),
        Response::Error { code: ErrorCode::Busy, .. } => (Outcome::Busy, None),
        other => panic!("unexpected response under chaos: {other:?}"),
    }
}

// ------------------------------------------------------ control

#[test]
fn disarmed_registry_serves_bit_identically() {
    let _seq = SEQ.lock().unwrap_or_else(|e| e.into_inner());
    assert!(!faults::armed(), "no plan may leak into the control test");
    let traffic: Vec<(usize, &'static str, Vec<f32>)> = (0..12)
        .map(|i| (i, MODELS[i % MODELS.len()], sample(0xC0_FFEE ^ i as u64)))
        .collect();
    let reference = reference_outputs(&traffic);

    let handle = serve("127.0.0.1:0", chaos_engine(4), ServerConfig::default())
        .expect("bind an ephemeral port");
    let mut client = connect(&handle);
    for (i, model, x) in &traffic {
        let out = client.infer(model, &SAMPLE_DIMS, x).expect("disarmed inference");
        assert!(bits_eq(&out, &reference[*i]), "request {i} diverged with the registry off");
    }
    let report = handle.shutdown().expect("clean shutdown");
    assert_eq!(report.served, traffic.len() as u64);
    assert_eq!(report.errored, 0);
    assert_eq!(report.panics, 0);
    assert!(report.quarantined.is_empty());
}

// ------------------------------------------------------ quarantine

#[test]
fn panicking_model_is_quarantined_while_others_keep_serving() {
    let _seq = SEQ.lock().unwrap_or_else(|e| e.into_inner());
    faults::silence_injected_panics();
    let traffic: Vec<(usize, &'static str, Vec<f32>)> =
        (0..4).map(|i| (i, "good", sample(0xBAD ^ i as u64))).collect();
    let reference = reference_outputs(&traffic);

    let guard = FaultPlan::new(21)
        .with(rule(faults::SITE_SERVE_STEP, Some("bad"), FaultKind::Panic, Trigger::Nth(1)))
        .arm();
    let handle = serve("127.0.0.1:0", chaos_engine(4), ServerConfig::default())
        .expect("bind an ephemeral port");

    let mut bad_client = connect(&handle);
    // First request: the wave panics, the supervisor answers INTERNAL
    // and quarantines (threshold 1).
    let x = sample(0xDEAD);
    let (outcome, _) = classify(bad_client.request("bad", &SAMPLE_DIMS, &x).expect("reply 1"));
    assert_eq!(outcome, Outcome::Internal, "the panicked wave must fail structurally");
    // Second request: refused at admission.
    let (outcome, _) = classify(bad_client.request("bad", &SAMPLE_DIMS, &x).expect("reply 2"));
    assert_eq!(outcome, Outcome::Quarantined, "strike 1 must quarantine the model");

    // The other model keeps serving bit-identically on a second
    // connection, concurrent with the quarantined one.
    let mut good_client = connect(&handle);
    for (i, _, x) in &traffic {
        let out = good_client.infer("good", &SAMPLE_DIMS, x).expect("good model inference");
        assert!(bits_eq(&out, &reference[*i]), "good request {i} diverged after the panic");
    }
    let health = good_client.health().expect("health frame");
    assert_eq!(health.panics, 1);
    assert_eq!(health.quarantined.len(), 1);
    assert_eq!(health.quarantined[0].model, "bad");
    assert_eq!(health.quarantined[0].strikes, 1);

    drop(bad_client);
    drop(good_client);
    let report = handle.shutdown().expect("clean shutdown");
    assert_eq!(report.served, traffic.len() as u64);
    assert_eq!(report.panics, 1);
    assert_eq!(report.quarantine_rejected, 1);
    assert_eq!(report.quarantined.len(), 1);
    drop(guard);
}

// ------------------------------------------------------ metrics

#[test]
fn injected_panics_surface_in_the_metrics_frame() {
    let _seq = SEQ.lock().unwrap_or_else(|e| e.into_inner());
    faults::silence_injected_panics();

    let guard = FaultPlan::new(33)
        .with(rule(faults::SITE_SERVE_STEP, Some("bad"), FaultKind::Panic, Trigger::Nth(1)))
        .arm();
    let handle = serve("127.0.0.1:0", chaos_engine(4), ServerConfig::default())
        .expect("bind an ephemeral port");
    let mut client = connect(&handle);
    let x = sample(0x0B5_BAD);
    let (outcome, _) = classify(client.request("bad", &SAMPLE_DIMS, &x).expect("reply"));
    assert_eq!(outcome, Outcome::Internal, "the panicked wave must fail structurally");

    // The caught panic is visible on the kind-7 exposition: the panic
    // and error counters tick, and the per-model error histogram names
    // the model whose wave died.
    let text = client.metrics().expect("metrics frame");
    let scraped = |name: &str| gconv_chain::obs::export::scrape(&text, name);
    assert_eq!(scraped("gconv_panics"), Some(1), "{text}");
    assert_eq!(scraped("gconv_errored"), Some(1), "{text}");
    assert_eq!(scraped("gconv_model_error_ns_bad_count"), Some(1), "{text}");

    drop(client);
    let report = handle.shutdown().expect("clean shutdown");
    assert_eq!(report.panics, 1);
    drop(guard);
}

// ------------------------------------------------------ soak

/// The full randomized soak: three concurrent clients, mixed traffic
/// across three models, four armed fault rules over three sites
/// (panic, graceful error, and delays at two layers). Fixed seed; CI
/// runs it in release via `--ignored`.
#[test]
#[ignore = "multi-second chaos soak; CI runs it in release via `-- --ignored`"]
fn chaos_soak_under_randomized_faults() {
    const CLIENTS: usize = 3;
    const PER_CLIENT: usize = 30;
    const QUEUE_DEPTH: usize = 8;

    let _seq = SEQ.lock().unwrap_or_else(|e| e.into_inner());
    faults::silence_injected_panics();

    // Client `c` takes indices `c, c+CLIENTS, …`, so the model is keyed
    // on `i / CLIENTS`: every client cycles through all three models
    // (keyed on `i` it would be model-homogeneous — CLIENTS ≡ MODELS).
    let traffic: Vec<(usize, &'static str, Vec<f32>)> = (0..CLIENTS * PER_CLIENT)
        .map(|i| (i, MODELS[(i / CLIENTS) % MODELS.len()], sample(0x50AC ^ i as u64)))
        .collect();
    let reference = reference_outputs(&traffic);

    let guard = FaultPlan::new(4242)
        // `bad` panics on its second wave: one strike → quarantined.
        .with(rule(faults::SITE_SERVE_STEP, Some("bad"), FaultKind::Panic, Trigger::Nth(2)))
        // `flaky` waves fail gracefully one time in five.
        .with(rule(faults::SITE_SCHEDULER_WAVE, Some("flaky"), FaultKind::Err, Trigger::Prob(0.2)))
        // `flaky` steps stall a little, one in three.
        .with(rule(
            faults::SITE_SERVE_STEP,
            Some("flaky"),
            FaultKind::Delay(Duration::from_millis(1)),
            Trigger::Prob(0.3),
        ))
        // Every connection's frames are randomly delayed.
        .with(rule(
            faults::SITE_CONN_READ,
            None,
            FaultKind::Delay(Duration::from_millis(2)),
            Trigger::Prob(0.1),
        ))
        .arm();

    let config = ServerConfig { queue_depth: QUEUE_DEPTH, ..ServerConfig::default() };
    let handle =
        serve("127.0.0.1:0", chaos_engine(4), config).expect("bind an ephemeral port");

    // Each client drives its slice of the traffic and records one
    // outcome per request — a missing or doubled reply would corrupt
    // the accounting below.
    let outcomes = std::thread::scope(|scope| {
        let mut workers = Vec::with_capacity(CLIENTS);
        for c in 0..CLIENTS {
            let handle = &handle;
            let traffic = &traffic;
            workers.push(scope.spawn(move || {
                let mut client = connect(handle);
                let mut got: Vec<(usize, Outcome, Option<Vec<f32>>)> = Vec::new();
                for (i, model, x) in traffic.iter().skip(c).step_by(CLIENTS) {
                    loop {
                        let resp =
                            client.request(model, &SAMPLE_DIMS, x).expect("one reply per request");
                        let (outcome, data) = classify(resp);
                        if outcome == Outcome::Busy {
                            std::thread::sleep(Duration::from_millis(2));
                            continue;
                        }
                        got.push((*i, outcome, data));
                        break;
                    }
                }
                got
            }));
        }
        let mut all = Vec::new();
        for w in workers {
            all.extend(w.join().expect("chaos client thread"));
        }
        all
    });

    // Exactly one terminal outcome per request.
    assert_eq!(outcomes.len(), traffic.len());

    let mut internal = 0u64;
    let mut quarantined = 0u64;
    for (i, outcome, data) in &outcomes {
        let model = traffic[*i].1;
        match outcome {
            // Injection never corrupts a success, whatever the model.
            Outcome::Output => {
                let out = data.as_ref().expect("output carries data");
                assert!(bits_eq(out, &reference[*i]), "successful request {i} diverged");
            }
            Outcome::Internal => {
                assert_ne!(model, "good", "the clean model must never fail internally");
                internal += 1;
            }
            Outcome::Quarantined => {
                assert_eq!(model, "bad", "only the panicking model may be quarantined");
                quarantined += 1;
            }
            Outcome::Busy => unreachable!("BUSY is retried in the client loop"),
        }
    }

    // The health frame closes the books while the server still runs.
    let mut probe = connect(&handle);
    let health = probe.health().expect("health frame");
    assert_eq!(
        health.submitted,
        health.completed + health.errored + health.expired,
        "accepted requests must all resolve: {health:?}"
    );
    assert_eq!(health.queue_depth, 0, "nothing may linger in the queue after the soak");
    assert!(health.max_queue_depth <= QUEUE_DEPTH as u64, "queue bound violated: {health:?}");
    assert_eq!(health.panics, 1, "the Nth(2) panic rule fires exactly once");
    assert_eq!(health.quarantined.len(), 1);
    assert_eq!(health.quarantined[0].model, "bad");
    drop(probe);

    let report = handle.shutdown().expect("clean shutdown under chaos");
    assert!(report.max_queue_depth <= QUEUE_DEPTH);
    assert_eq!(report.panics, 1);
    // A QUARANTINED reply is either an admission reject
    // (`quarantine_rejected`) or a wave-time fail for a job accepted
    // just before the strike landed (`errored`); together with the
    // INTERNAL replies the books close exactly against what the
    // clients saw.
    assert_eq!(
        report.errored + report.quarantine_rejected,
        internal + quarantined,
        "every error frame the clients saw must be accounted: {report:?}"
    );
    assert!(report.quarantine_rejected <= quarantined);
    drop(guard);
}
