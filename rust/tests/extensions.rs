//! Extension coverage beyond the paper's Table-4 set: §4.4 generality
//! (a sixth accelerator structure), the §3.1 "real-time learning"
//! remark (batch-1 chains prune their B loops), and failure-injection /
//! edge-case behaviour of the coordinator.

use gconv_chain::accel::configs::simba;
use gconv_chain::gconv::lower::{lower_network, Mode};
use gconv_chain::gconv::op::Param;
use gconv_chain::ir::Dim;
use gconv_chain::mapping::{map_gconv, MapMode};
use gconv_chain::networks::{benchmark, mobilenet_block};
use gconv_chain::sim::{simulate, ExecMode, SimOptions};

#[test]
fn algorithm1_generalizes_to_simba() {
    // §4.4: a structure never seen by the mapper's design must map every
    // benchmark without modification and keep the GCONV-chain benefits.
    let accel = simba();
    for code in ["AN", "MN", "CapNN"] {
        let net = benchmark(code);
        let base = simulate(&net, &accel, SimOptions { mode: ExecMode::Baseline, training: true });
        let gc = simulate(&net, &accel, SimOptions { mode: ExecMode::GconvChain, training: true });
        assert!(gc.seconds.is_finite() && gc.seconds > 0.0);
        assert_eq!(gc.movement.offload, 0.0);
        let s = base.seconds / gc.seconds;
        assert!(s > 0.8, "{code} on Simba: speedup {s:.2}");
    }
}

#[test]
fn simba_reduce_axis_hosts_ks_loops() {
    // The only reduce-capable axis must receive every spatial ks loop.
    let accel = simba();
    let chain = lower_network(&benchmark("AN"), Mode::Inference);
    for e in chain.entries() {
        let m = map_gconv(&e.op, &accel, MapMode::Gconv);
        for entry in &m.spatial[0] {
            assert_ne!(
                entry.param,
                Param::Ks,
                "{}: ks spatially unrolled on a non-reduce axis",
                e.op.name
            );
        }
    }
}

#[test]
fn realtime_learning_prunes_batch_loops() {
    // §3.1: "we can remove the four loops in dimension B to model the
    // real-time learning" — with batch 1 the lowered chain must carry no
    // effective B loops.
    let net = mobilenet_block(1, 16, 14);
    let chain = lower_network(&net, Mode::Training);
    for e in chain.entries() {
        let p = e.op.params(Dim::B);
        for param in Param::ALL {
            assert_eq!(p.get(param), 1, "{}: B loop survived batch-1 lowering", e.op.name);
        }
    }
    // And batch-32 work is ~32x the batch-1 work (BN reductions scale too).
    let w1 = chain.total_work() as f64;
    let w32 = lower_network(&mobilenet_block(32, 16, 14), Mode::Training).total_work() as f64;
    let ratio = w32 / w1;
    assert!((28.0..36.0).contains(&ratio), "work ratio {ratio:.1}");
}

#[test]
fn coordinator_rejects_bad_samples_and_handles_partial_batches() {
    // Runs on the native backend — no artifacts, no PJRT.
    use gconv_chain::coordinator::{ChainExecutor, Request};
    let (b, c, hw) = (8usize, 16usize, 14usize);
    let mut rng = gconv_chain::prop::Rng::new(9);
    let mut rand = |n: usize| -> Vec<f32> { (0..n).map(|_| rng.f64() as f32 - 0.5).collect() };
    let mut exec = ChainExecutor::for_network(&mobilenet_block(b, c, hw)).unwrap();
    assert_eq!(exec.backend_name(), "native");

    // Failure injection: wrong sample length must be rejected up front.
    assert!(exec.submit(Request { id: 0, data: vec![0.0; 7] }).is_err());
    assert_eq!(exec.pending(), 0);

    // Partial batch: 3 samples < batch 8 — no execution without flush…
    for id in 0..3 {
        exec.submit(Request { id, data: rand(c * hw * hw) }).unwrap();
    }
    assert!(exec.step(false).unwrap().is_empty());
    assert_eq!(exec.pending(), 3);
    // …but a flush pads and serves all three, preserving order.
    let out = exec.step(true).unwrap();
    assert_eq!(out.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
    assert_eq!(exec.pending(), 0);
    assert!(out.iter().all(|r| r.data.len() == 2 * c * hw * hw));
    assert!(out.iter().all(|r| r.data.iter().all(|v| v.is_finite())));
}

#[cfg(feature = "pjrt")]
#[test]
fn runtime_rejects_wrong_arity() {
    use gconv_chain::runtime::{literal_f32, Runtime};
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let mut rt = Runtime::cpu("artifacts").unwrap();
    // gconv_generic expects two inputs; give it one.
    let x = literal_f32(&vec![0.0; 4 * 8 * 12 * 12], &[4, 8, 12, 12]).unwrap();
    assert!(rt.execute("gconv_generic", &[x]).is_err());
}

#[test]
fn inference_chains_skip_backward_ops() {
    use gconv_chain::gconv::chain::Phase;
    for code in ["AN", "MN"] {
        let chain = lower_network(&benchmark(code), Mode::Inference);
        assert!(chain.entries().iter().all(|e| e.phase == Phase::Fp), "{code}");
    }
}

#[test]
fn degenerate_single_pixel_network_simulates() {
    // Edge case: 1x1 spatial extents everywhere.
    use gconv_chain::ir::{Layer, Network, Shape};
    let mut net = Network::new("tiny");
    let i = net.add("data", Layer::Input { shape: Shape::bchw(2, 4, 1, 1) }, &[]);
    let c = net.add(
        "conv",
        Layer::Conv { out_channels: 8, kernel: (1, 1), stride: 1, pad: 0, groups: 1 },
        &[i],
    );
    net.add("sm", Layer::Softmax, &[c]);
    for a in gconv_chain::accel::configs::all_accelerators() {
        let r = simulate(&net, &a, SimOptions { mode: ExecMode::GconvChain, training: true });
        assert!(r.seconds > 0.0 && r.seconds.is_finite(), "{}", a.name);
    }
}
