//! Property-based tests over the compiler/mapper/model invariants,
//! using the in-repo generator (`gconv_chain::prop`).

use gconv_chain::accel::configs::all_accelerators;
use gconv_chain::gconv::op::{DataRef, DimParams, GconvOp, MainOp, Param, PostOp, PreOp, ReduceOp};
use gconv_chain::ir::Dim;
use gconv_chain::isa::{decode_unrolling, encode};
use gconv_chain::mapping::{map_gconv, MapMode};
use gconv_chain::model::cycles::compute_cycles;
use gconv_chain::model::movement::gconv_movement;
use gconv_chain::prop::{prop_check, Rng};

/// Generate a random (but well-formed) GCONV op.
fn arb_op(rng: &mut Rng) -> GconvOp {
    let mut dims = Vec::new();
    if rng.bool(0.8) {
        dims.push((Dim::B, DimParams::opc(rng.int(1, 32))));
    }
    match rng.int(0, 2) {
        0 => dims.push((
            Dim::C,
            DimParams { nop: rng.int(1, 64), nks: rng.int(1, 32), ..Default::default() },
        )),
        1 => dims.push((Dim::C, DimParams::g(rng.int(1, 64)))),
        _ => dims.push((Dim::C, DimParams::opc(rng.int(1, 64)))),
    }
    for d in [Dim::H, Dim::W] {
        if rng.bool(0.7) {
            let ks = rng.int(1, 5);
            let s = rng.int(1, 2);
            let opc = rng.int(1, 28);
            let ps = rng.int(0, ks / 2);
            dims.push((d, DimParams { nopc: opc, nks: ks, s, ps, ..Default::default() }));
        }
    }
    let kernel_less = rng.bool(0.3);
    GconvOp {
        name: "prop".into(),
        dims,
        pre: *rng.choose(&[PreOp::None, PreOp::Square]),
        main: if kernel_less {
            MainOp::Pass
        } else {
            *rng.choose(&[MainOp::Mul, MainOp::Add, MainOp::Sub])
        },
        reduce: *rng.choose(&[ReduceOp::Add, ReduceOp::Max, ReduceOp::None]),
        post: *rng.choose(&[PostOp::None, PostOp::Lut("relu")]),
        input: DataRef::External("x".into()),
        kernel: if kernel_less { None } else { Some(DataRef::Weights("w".into())) },
    }
}

#[test]
fn mapping_covers_every_loop() {
    // Σ spatial×temporal factors must cover each loop's full count.
    prop_check(300, |rng| {
        let op = arb_op(rng);
        let accels = all_accelerators();
        let accel = rng.choose(&accels);
        let mode = if rng.bool(0.5) { MapMode::Gconv } else { MapMode::Baseline };
        let m = map_gconv(&op, accel, mode);
        for &(d, dp) in &op.dims {
            for p in Param::ALL {
                let n = dp.get(p);
                let sp = m.spatial_factor(d, p);
                let tp: usize = m
                    .temporal
                    .iter()
                    .filter(|e| e.dim == d && e.param == p)
                    .map(|e| e.factor)
                    .product();
                if sp * tp < n {
                    return Err(format!(
                        "{}: loop [{d}][{p}]={n} uncovered (sp {sp} x tp {tp}) for {op}",
                        accel.name
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn occupied_pes_within_array() {
    prop_check(300, |rng| {
        let op = arb_op(rng);
        let accels = all_accelerators();
        let accel = rng.choose(&accels);
        let m = map_gconv(&op, accel, MapMode::Gconv);
        if m.occupied_pes() > accel.pes() {
            return Err(format!("{} PEs > {}", m.occupied_pes(), accel.pes()));
        }
        Ok(())
    });
}

#[test]
fn cycles_bounded_by_work_and_parallelism() {
    // work/PEs ≤ Eq.(6) cycles ≤ work (ceil losses only raise the bound).
    prop_check(300, |rng| {
        let op = arb_op(rng);
        let accels = all_accelerators();
        let accel = rng.choose(&accels);
        let m = map_gconv(&op, accel, MapMode::Gconv);
        let c = compute_cycles(&op, &m);
        let work = op.work() as f64;
        if c < work / accel.pes() as f64 * 0.999 {
            return Err(format!(
                "{}: cycles {c} < work/PEs {}",
                accel.name,
                work / accel.pes() as f64
            ));
        }
        if c > work * 1.001 {
            return Err(format!("{}: cycles {c} > work {work}", accel.name));
        }
        Ok(())
    });
}

#[test]
fn movement_bounded_below_by_unique_data() {
    prop_check(300, |rng| {
        let op = arb_op(rng);
        let accels = all_accelerators();
        let accel = rng.choose(&accels);
        let m = map_gconv(&op, accel, MapMode::Gconv);
        let mv = gconv_movement(&op, accel, &m);
        if mv.input < op.input_elements() as f64 * 0.99 {
            return Err(format!("input movement {} < unique {}", mv.input, op.input_elements()));
        }
        if mv.output < op.output_elements() as f64 * 0.99 {
            return Err(format!(
                "output movement {} < unique {}",
                mv.output,
                op.output_elements()
            ));
        }
        if op.kernel.is_some() && mv.kernel < op.kernel_elements() as f64 * 0.99 {
            return Err(format!(
                "kernel movement {} < unique {}",
                mv.kernel,
                op.kernel_elements()
            ));
        }
        Ok(())
    });
}

#[test]
fn isa_encoding_round_trips_unrolling_lists() {
    prop_check(200, |rng| {
        let op = arb_op(rng);
        let accels = all_accelerators();
        let accel = rng.choose(&accels);
        let m = map_gconv(&op, accel, MapMode::Gconv);
        let prog = encode(&op, &m);
        let lists = decode_unrolling(&prog.unrolling);
        if lists.len() != m.spatial.len() + 1 {
            return Err(format!("list count {} != {}", lists.len(), m.spatial.len() + 1));
        }
        for (axis, decoded) in m.spatial.iter().zip(&lists) {
            if axis != decoded {
                return Err("spatial list mismatch".into());
            }
        }
        if &m.temporal != lists.last().unwrap() {
            return Err("temporal list mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn fusion_preserves_reduce_work() {
    // Fused chains drop only reduce-free ops; total reduce-op work is
    // invariant and references stay backward.
    use gconv_chain::gconv::lower::{lower_network, Mode};
    use gconv_chain::ir::{Layer, Network, PoolKind, Shape};
    use gconv_chain::mapping::fuse_chain;

    prop_check(40, |rng| {
        let mut net = Network::new("prop");
        let mut prev = net.add(
            "data",
            Layer::Input { shape: Shape::bchw(rng.int(1, 8), rng.int(1, 8), 8, 8) },
            &[],
        );
        for i in 0..rng.int(1, 5) {
            let c = rng.int(1, 16);
            prev = net.add(
                &format!("conv{i}"),
                Layer::Conv { out_channels: c, kernel: (3, 3), stride: 1, pad: 1, groups: 1 },
                &[prev],
            );
            match rng.int(0, 3) {
                0 => prev = net.add(&format!("bn{i}"), Layer::BatchNorm, &[prev]),
                1 => prev = net.add(&format!("relu{i}"), Layer::Relu, &[prev]),
                2 => {
                    prev = net.add(
                        &format!("pool{i}"),
                        Layer::Pool { kind: PoolKind::Max, kernel: 2, stride: 2, pad: 0 },
                        &[prev],
                    )
                }
                _ => {}
            }
        }
        let mut chain = lower_network(&net, Mode::Training);
        let reduce_work_before: usize = chain
            .entries()
            .iter()
            .filter(|e| e.op.reduce != ReduceOp::None)
            .map(|e| e.op.work())
            .sum();
        fuse_chain(&mut chain);
        let reduce_work_after: usize = chain
            .entries()
            .iter()
            .filter(|e| e.op.reduce != ReduceOp::None)
            .map(|e| e.op.work())
            .sum();
        if reduce_work_before != reduce_work_after {
            return Err(format!("reduce work {reduce_work_before} -> {reduce_work_after}"));
        }
        for (i, e) in chain.entries().iter().enumerate() {
            if let DataRef::Gconv(p) = e.op.input {
                if p >= i {
                    return Err(format!("entry {i} references {p}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn lowering_never_panics_on_valid_stacks() {
    use gconv_chain::gconv::lower::{lower_network, Mode};
    use gconv_chain::ir::{Layer, Network, PoolKind, Shape};
    prop_check(100, |rng| {
        let mut net = Network::new("prop");
        let mut prev = net.add(
            "data",
            Layer::Input { shape: Shape::bchw(rng.int(1, 4), rng.int(1, 8), 16, 16) },
            &[],
        );
        for i in 0..rng.int(1, 8) {
            let h = net.node(prev).output.extent(Dim::H);
            prev = match rng.int(0, 4) {
                0 => net.add(
                    &format!("c{i}"),
                    Layer::Conv {
                        out_channels: rng.int(1, 16),
                        kernel: (3, 3),
                        stride: 1,
                        pad: 1,
                        groups: 1,
                    },
                    &[prev],
                ),
                1 if h >= 2 => net.add(
                    &format!("p{i}"),
                    Layer::Pool { kind: PoolKind::Max, kernel: 2, stride: 2, pad: 0 },
                    &[prev],
                ),
                2 => net.add(&format!("b{i}"), Layer::BatchNorm, &[prev]),
                3 => net.add(&format!("s{i}"), Layer::Sigmoid, &[prev]),
                _ => net.add(&format!("r{i}"), Layer::Relu, &[prev]),
            };
        }
        let inf = lower_network(&net, Mode::Inference);
        let trn = lower_network(&net, Mode::Training);
        if trn.len() < inf.len() {
            return Err("training chain shorter than inference".into());
        }
        Ok(())
    });
}
