//! Integration suite for the TCP serving front (`gconv_chain::server`).
//!
//! Three concerns, mirroring the conformance discipline of the
//! in-process engine:
//!
//! * **Wire conformance** — concurrent TCP clients must receive
//!   responses bit-identical to in-process `Engine::submit`/`drain`
//!   over the same deterministically synthesized weights.
//! * **Protocol hardening** — malformed, truncated, and oversized
//!   frames, unknown models, bad shapes, slow clients, and mid-frame
//!   disconnects must be answered with structured errors (or a clean
//!   close) without taking the server down.
//! * **Backpressure + shutdown** — a request flood must be rejected
//!   with `BUSY` at the bounded queue (never buffered unboundedly),
//!   while admitted requests complete bit-identically; graceful
//!   shutdown must drain in-flight work.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use gconv_chain::exec::serve::Engine;
use gconv_chain::exec::Tensor;
use gconv_chain::ir::{Layer, Network, Shape};
use gconv_chain::server::protocol::{self, ErrorCode, Response, HEADER_LEN, MAGIC};
use gconv_chain::server::{serve, Client, ServerConfig, ServerHandle};

const SAMPLE_DIMS: [usize; 3] = [2, 4, 4];
const SAMPLE_LEN: usize = 2 * 4 * 4;

/// conv → ReLU → FC at 2×4×4 — small enough for tight test loops, deep
/// enough to exercise real numerics.
fn tiny_net(batch: usize) -> Network {
    let mut net = Network::new("tiny");
    let i = net.add("data", Layer::Input { shape: Shape::bchw(batch, 2, 4, 4) }, &[]);
    let c = net.add(
        "conv",
        Layer::Conv { out_channels: 3, kernel: (3, 3), stride: 1, pad: 1, groups: 1 },
        &[i],
    );
    let r = net.add("relu", Layer::Relu, &[c]);
    net.add("fc", Layer::FullyConnected { out_features: 5 }, &[r]);
    net
}

fn tiny_engine(max_batch: usize) -> Engine {
    let mut engine = Engine::new(max_batch);
    engine.register("tiny", tiny_net);
    engine
}

fn sample(seed: u64) -> Vec<f32> {
    Tensor::rand(&[SAMPLE_LEN], seed, 1.0).into_data()
}

/// In-process reference outputs for `inputs`, in order — the oracle
/// every wire response is pinned against.
fn reference_outputs(inputs: &[Vec<f32>]) -> Vec<Vec<f32>> {
    let mut engine = tiny_engine(1);
    for (id, x) in inputs.iter().enumerate() {
        engine.submit("tiny", id as u64, x.clone()).unwrap();
    }
    let mut responses = engine.drain().unwrap();
    responses.sort_by_key(|r| r.id);
    responses.into_iter().map(|r| r.data).collect()
}

fn start(engine: Engine, config: ServerConfig) -> ServerHandle {
    serve("127.0.0.1:0", engine, config).expect("server must bind an ephemeral port")
}

fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

// ------------------------------------------------------ conformance

#[test]
fn concurrent_clients_are_bit_identical_to_the_in_process_engine() {
    const CLIENTS: usize = 3;
    const PER_CLIENT: usize = 4;
    let inputs: Vec<Vec<f32>> =
        (0..CLIENTS * PER_CLIENT).map(|i| sample(0xA11CE ^ i as u64)).collect();
    let reference = reference_outputs(&inputs);

    let handle = start(tiny_engine(4), ServerConfig::default());
    let addr = handle.addr().to_string();
    std::thread::scope(|scope| {
        let mut workers = Vec::new();
        for c in 0..CLIENTS {
            let addr = addr.clone();
            let inputs = &inputs;
            let reference = &reference;
            workers.push(scope.spawn(move || {
                let mut client =
                    Client::connect_retry(&addr, Duration::from_secs(10)).expect("connect");
                for i in (c..inputs.len()).step_by(CLIENTS) {
                    let out = client
                        .infer("tiny", &SAMPLE_DIMS, &inputs[i])
                        .expect("inference over the wire");
                    assert!(bits_eq(&out, &reference[i]), "request {i} diverged over the wire");
                }
            }));
        }
        for w in workers {
            w.join().expect("client thread");
        }
    });
    let report = handle.shutdown().expect("clean shutdown");
    assert_eq!(report.served, (CLIENTS * PER_CLIENT) as u64);
    assert_eq!(report.conns_accepted, CLIENTS as u64);
    assert_eq!(report.errored, 0);
    assert_eq!(report.engine.requests, CLIENTS * PER_CLIENT);
}

#[test]
fn one_connection_can_issue_many_requests_and_survive_request_errors() {
    let inputs: Vec<Vec<f32>> = (0..3).map(|i| sample(7 ^ i as u64)).collect();
    let reference = reference_outputs(&inputs);
    let handle = start(tiny_engine(2), ServerConfig::default());
    let mut client =
        Client::connect_retry(&handle.addr().to_string(), Duration::from_secs(10)).unwrap();

    // Unknown model: structured error, connection stays usable.
    match client.request("no-such-model", &SAMPLE_DIMS, &inputs[0]).unwrap() {
        Response::Error { code, message } => {
            assert_eq!(code, ErrorCode::UnknownModel);
            assert!(message.contains("no-such-model"), "{message}");
        }
        other => panic!("expected an error frame, got {other:?}"),
    }
    // Wrong element count: BAD_SHAPE, connection stays usable.
    match client.request("tiny", &[3], &[0.0; 3]).unwrap() {
        Response::Error { code, .. } => assert_eq!(code, ErrorCode::BadShape),
        other => panic!("expected an error frame, got {other:?}"),
    }
    // The same connection then serves real requests bit-identically.
    for (i, x) in inputs.iter().enumerate() {
        let out = client.infer("tiny", &SAMPLE_DIMS, x).unwrap();
        assert!(bits_eq(&out, &reference[i]));
    }
    let report = handle.shutdown().unwrap();
    assert_eq!(report.served, inputs.len() as u64);
    assert_eq!(report.errored, 2);
}

#[test]
fn metrics_frame_matches_the_health_snapshot_field_for_field() {
    use gconv_chain::obs::export::scrape;
    use gconv_chain::server::protocol::HEALTH_FIELDS;

    let inputs: Vec<Vec<f32>> = (0..3).map(|i| sample(0x0B5 ^ i as u64)).collect();
    let reference = reference_outputs(&inputs);
    let handle = start(tiny_engine(2), ServerConfig::default());
    let mut client =
        Client::connect_retry(&handle.addr().to_string(), Duration::from_secs(10)).unwrap();
    // A known workload: three served requests and one structured error.
    for (i, x) in inputs.iter().enumerate() {
        let out = client.infer("tiny", &SAMPLE_DIMS, x).unwrap();
        assert!(bits_eq(&out, &reference[i]));
    }
    match client.request("no-such-model", &SAMPLE_DIMS, &inputs[0]).unwrap() {
        Response::Error { code, .. } => assert_eq!(code, ErrorCode::UnknownModel),
        other => panic!("expected an error frame, got {other:?}"),
    }
    // The kind-7 exposition and the health snapshot are two views of
    // one registry: every wire health field must scrape back
    // identically under its `gconv_` metric name.
    let text = client.metrics().expect("metrics frame");
    let h = client.health().expect("health frame");
    for field in HEALTH_FIELDS {
        assert_eq!(
            scrape(&text, &format!("gconv_{}", field.name)),
            Some((field.get)(&h)),
            "field {} diverged between the exposition and the snapshot:\n{text}",
            field.name
        );
    }
    // The stage histograms observed the served requests: one eval span
    // per completion, one read span per inbound frame (4 requests plus
    // the metrics probe itself).
    assert_eq!(scrape(&text, "gconv_eval_ns_count"), Some(3));
    assert!(scrape(&text, "gconv_read_ns_count").unwrap_or(0) >= 4, "{text}");
    let report = handle.shutdown().unwrap();
    // Status frames are budget-exempt: the report counts inferences.
    assert_eq!(report.served, 3);
    assert_eq!(report.errored, 1);
}

// -------------------------------------------------------- hardening

#[test]
fn bad_magic_gets_a_malformed_error_and_the_server_survives() {
    let handle = start(tiny_engine(2), ServerConfig::default());
    let addr = handle.addr();
    let mut raw = TcpStream::connect(addr).unwrap();
    raw.write_all(b"GET / HTTP/1.1\r\n\r\n").unwrap();
    let resp = protocol::read_response(&mut raw).expect("server answers before closing");
    match resp {
        Response::Error { code, .. } => assert_eq!(code, ErrorCode::Malformed),
        other => panic!("expected an error frame, got {other:?}"),
    }
    // Framing was lost, so that connection is closed…
    let mut probe = [0u8; 1];
    assert_eq!(raw.read(&mut probe).unwrap_or(0), 0, "connection must be closed");
    // …but the listener keeps serving fresh connections.
    let x = sample(11);
    let reference = reference_outputs(std::slice::from_ref(&x));
    let mut client =
        Client::connect_retry(&addr.to_string(), Duration::from_secs(10)).unwrap();
    let out = client.infer("tiny", &SAMPLE_DIMS, &x).unwrap();
    assert!(bits_eq(&out, &reference[0]));
    let report = handle.shutdown().unwrap();
    assert_eq!(report.malformed, 1);
}

#[test]
fn oversized_frames_are_refused_before_allocation() {
    let handle = start(tiny_engine(2), ServerConfig::default());
    let mut raw = TcpStream::connect(handle.addr()).unwrap();
    let mut header = Vec::from(MAGIC);
    header.extend_from_slice(&u32::MAX.to_le_bytes());
    assert_eq!(header.len(), HEADER_LEN);
    raw.write_all(&header).unwrap();
    match protocol::read_response(&mut raw).expect("server answers before closing") {
        Response::Error { code, .. } => assert_eq!(code, ErrorCode::TooLarge),
        other => panic!("expected an error frame, got {other:?}"),
    }
    let report = handle.shutdown().unwrap();
    assert_eq!(report.malformed, 1);
    assert_eq!(report.served, 0);
}

#[test]
fn mid_frame_disconnect_does_not_take_the_server_down() {
    let handle = start(tiny_engine(2), ServerConfig::default());
    let addr = handle.addr();
    {
        // A valid header promising 64 bytes, then half a body, then gone.
        let frame = protocol::encode_request("tiny", &SAMPLE_DIMS, &sample(3)).unwrap();
        let mut raw = TcpStream::connect(addr).unwrap();
        raw.write_all(&frame[..frame.len() / 2]).unwrap();
    } // dropped mid-frame
    let x = sample(4);
    let reference = reference_outputs(std::slice::from_ref(&x));
    let mut client =
        Client::connect_retry(&addr.to_string(), Duration::from_secs(10)).unwrap();
    let out = client.infer("tiny", &SAMPLE_DIMS, &x).unwrap();
    assert!(bits_eq(&out, &reference[0]));
    let report = handle.shutdown().unwrap();
    assert_eq!(report.served, 1);
}

#[test]
fn slow_clients_are_dropped_at_the_frame_deadline() {
    let config = ServerConfig { read_timeout: Duration::from_millis(200), ..Default::default() };
    let handle = start(tiny_engine(2), config);
    let mut raw = TcpStream::connect(handle.addr()).unwrap();
    // First header byte arrives, then the client stalls past the
    // deadline.
    raw.write_all(&MAGIC[..1]).unwrap();
    raw.flush().unwrap();
    match protocol::read_response(&mut raw).expect("server answers before dropping") {
        Response::Error { code, .. } => assert_eq!(code, ErrorCode::Timeout),
        other => panic!("expected an error frame, got {other:?}"),
    }
    let report = handle.shutdown().unwrap();
    assert_eq!(report.slow_clients, 1);
}

#[test]
fn connection_cap_refuses_with_busy_and_keeps_existing_conns_working() {
    let config = ServerConfig { max_conns: 1, ..Default::default() };
    let handle = start(tiny_engine(2), config);
    let addr = handle.addr().to_string();
    let x = sample(21);
    let reference = reference_outputs(std::slice::from_ref(&x));
    let mut first = Client::connect_retry(&addr, Duration::from_secs(10)).unwrap();
    // Prime the connection so the accept loop has registered it.
    let out = first.infer("tiny", &SAMPLE_DIMS, &x).unwrap();
    assert!(bits_eq(&out, &reference[0]));
    // The second connection is refused with a structured BUSY frame.
    let mut second = TcpStream::connect(handle.addr()).unwrap();
    match protocol::read_response(&mut second).expect("refused conn still gets an answer") {
        Response::Error { code, .. } => assert_eq!(code, ErrorCode::Busy),
        other => panic!("expected an error frame, got {other:?}"),
    }
    // The first connection keeps serving.
    let out = first.infer("tiny", &SAMPLE_DIMS, &x).unwrap();
    assert!(bits_eq(&out, &reference[0]));
    let report = handle.shutdown().unwrap();
    assert_eq!(report.conns_rejected, 1);
    assert_eq!(report.conns_accepted, 1);
}

// ------------------------------------------- backpressure + shutdown

/// `tiny_net` behind a builder that sleeps: session construction (which
/// runs on the engine driver thread at first use) holds requests
/// in-flight long enough for concurrent submissions to hit the
/// admission caps deterministically.
fn slow_engine(max_batch: usize, delay: Duration) -> Engine {
    let mut engine = Engine::new(max_batch);
    engine.register("tiny", move |batch| {
        std::thread::sleep(delay);
        tiny_net(batch)
    });
    engine
}

#[test]
fn request_flood_is_rejected_busy_while_admitted_requests_complete() {
    const FLOOD: usize = 6;
    let config = ServerConfig {
        queue_depth: 2,
        per_model_inflight: 1,
        ..Default::default()
    };
    let handle = start(slow_engine(4, Duration::from_millis(300)), config);
    let addr = handle.addr().to_string();
    let inputs: Vec<Vec<f32>> = (0..FLOOD).map(|i| sample(0xF100D ^ i as u64)).collect();
    let reference = reference_outputs(&inputs);

    let (outputs, busy_total) = std::thread::scope(|scope| {
        let mut workers = Vec::new();
        for x in &inputs {
            let addr = addr.clone();
            workers.push(scope.spawn(move || {
                let mut client =
                    Client::connect_retry(&addr, Duration::from_secs(10)).expect("connect");
                // Everyone floods at once; `BUSY` rejections are
                // retried until the request is admitted.
                client
                    .infer_retry_busy("tiny", &SAMPLE_DIMS, x, 10_000, Duration::from_millis(2))
                    .expect("flooded request must eventually complete")
            }));
        }
        let mut outputs = Vec::new();
        let mut busy_total = 0u64;
        for w in workers {
            let (out, busy) = w.join().expect("client thread");
            outputs.push(out);
            busy_total += u64::from(busy);
        }
        (outputs, busy_total)
    });

    for (i, out) in outputs.iter().enumerate() {
        assert!(bits_eq(out, &reference[i]), "flooded request {i} diverged");
    }
    let report = handle.shutdown().unwrap();
    // The flood was rejected at the admission/queue bound at least
    // once (six concurrent requests, one admitted at a time), clients
    // absorbed exactly those rejections, and the queue never grew past
    // its configured depth.
    assert!(report.rejected_busy > 0, "a six-way flood must hit BUSY backpressure");
    assert_eq!(report.rejected_busy, busy_total);
    assert!(report.max_queue_depth <= 2, "queue depth {} exceeded bound", report.max_queue_depth);
    assert_eq!(report.served, FLOOD as u64);
}

#[test]
fn graceful_shutdown_drains_in_flight_requests() {
    let handle = start(slow_engine(2, Duration::from_millis(400)), ServerConfig::default());
    let addr = handle.addr().to_string();
    let x = sample(0x5D01);
    let reference = reference_outputs(std::slice::from_ref(&x));

    let worker = {
        let addr = addr.clone();
        let x = x.clone();
        std::thread::spawn(move || {
            let mut client = Client::connect_retry(&addr, Duration::from_secs(10)).unwrap();
            client.infer("tiny", &SAMPLE_DIMS, &x)
        })
    };
    // Let the request reach the engine (the slow builder holds it
    // in-flight), then shut down mid-request.
    std::thread::sleep(Duration::from_millis(200));
    let report = handle.shutdown().expect("graceful shutdown");
    // The in-flight request was drained, not dropped…
    let out = worker.join().expect("client thread").expect("drained response");
    assert!(bits_eq(&out, &reference[0]), "drained request must stay bit-identical");
    assert_eq!(report.served, 1);
    assert_eq!(report.timeouts, 0);
}

#[test]
fn max_requests_stops_the_server_after_a_clean_drain() {
    const REQUESTS: usize = 3;
    let config = ServerConfig { max_requests: Some(REQUESTS as u64), ..Default::default() };
    let handle = start(tiny_engine(2), config);
    let addr = handle.addr().to_string();
    let inputs: Vec<Vec<f32>> = (0..REQUESTS).map(|i| sample(0xCAFE ^ i as u64)).collect();
    let reference = reference_outputs(&inputs);

    let worker = std::thread::spawn(move || {
        let mut client = Client::connect_retry(&addr, Duration::from_secs(10)).unwrap();
        inputs
            .iter()
            .map(|x| client.infer("tiny", &SAMPLE_DIMS, x).expect("inference"))
            .collect::<Vec<_>>()
    });
    // `wait` returns on its own once the request budget is served.
    let report = handle.wait().expect("self-stop");
    let outputs = worker.join().expect("client thread");
    for (i, out) in outputs.iter().enumerate() {
        assert!(bits_eq(out, &reference[i]));
    }
    assert_eq!(report.served, REQUESTS as u64);
}

// ---------------------------------------------------- protocol edges

#[test]
fn frames_round_trip_through_raw_sockets() {
    // encode/parse symmetry at the byte level, independent of the
    // server: what `Client` writes is what `conn` reads.
    let frame = protocol::encode_request("tiny", &SAMPLE_DIMS, &sample(1)).unwrap();
    assert_eq!(&frame[..4], &MAGIC);
    let body_len = u32::from_le_bytes(frame[4..8].try_into().unwrap()) as usize;
    assert_eq!(body_len, frame.len() - HEADER_LEN);
    let parsed = protocol::parse_request(&frame[HEADER_LEN..]).unwrap();
    assert_eq!(parsed.model, "tiny");
    assert_eq!(parsed.dims, SAMPLE_DIMS.to_vec());
    assert_eq!(parsed.data.len(), SAMPLE_LEN);
}

#[test]
fn error_codes_survive_the_wire() {
    let resp = Response::Error { code: ErrorCode::Busy, message: "queue full".into() };
    let frame = protocol::encode_response(&resp).unwrap();
    let parsed = protocol::parse_response(&frame[HEADER_LEN..]).unwrap();
    assert_eq!(parsed, resp);
}
