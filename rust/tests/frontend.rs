//! Frontend conformance: bundled specs vs builders, spec-file
//! execution, and targeted inference failures.
//!
//! The bundled files under `rust/specs/` are the round-trip oracle for
//! the model frontend:
//!
//! * `parse(file)` must equal `export(builder)` attribute-for-attribute
//!   — the exporter, the importer and the committed files can only
//!   move together (regenerate intentionally with `UPDATE_SPECS=1`);
//! * `build(parse(file))` must equal the builder network node-for-node
//!   and lower to a structurally identical GCONV chain;
//! * spec-built networks must execute **bit-identically** to their
//!   builder twins (MN + AN in tier-1, all seven in the release
//!   `--ignored` run);
//! * a custom CNN that exists only as a spec file (`tinycnn.json`)
//!   must run bit-identically across every engine path — {naive
//!   oracle, fast tiers, fused chain, session reuse} — and through the
//!   serving engine;
//! * malformed specs must produce targeted errors naming the offending
//!   layer, never panics;
//! * specs that pass shape inference must additionally pass the static
//!   chain audit (`analysis::audit_chain`) — the `specs` CLI gate
//!   exits non-zero with the named rule when one does not.

use std::fs;

use gconv_chain::exec::bench::input_spec;
use gconv_chain::exec::serve::{Engine, Session};
use gconv_chain::exec::{ChainExec, Tensor};
use gconv_chain::frontend::{
    build_network, build_with_batch, discover_specs, export_json, export_network, load_spec,
    spec_dir, ModelSpec,
};
use gconv_chain::gconv::lower::{lower_network, Mode};
use gconv_chain::ir::Network;
use gconv_chain::mapping::fuse_executable;
use gconv_chain::networks::{benchmark_with_batch, paper_batch, BENCHMARK_CODES};

/// Input seed shared by the execution legs.
const SEED: u64 = 0x5EED_F11E;

/// The two networks' nodes must agree on everything observable.
fn assert_same_network(code: &str, built: &Network, want: &Network) {
    assert_eq!(built.name, want.name, "{code}: network name");
    assert_eq!(built.len(), want.len(), "{code}: node count");
    for (a, b) in built.nodes().iter().zip(want.nodes()) {
        assert_eq!(a.name, b.name, "{code}: node #{} name", b.id);
        assert_eq!(a.layer, b.layer, "{code}: layer {:?}", b.name);
        assert_eq!(a.inputs, b.inputs, "{code}: wiring of {:?}", b.name);
        assert_eq!(a.output, b.output, "{code}: output shape of {:?}", b.name);
    }
}

/// The two specs must agree layer-for-layer (targeted failure output —
/// a whole-spec `assert_eq!` would dump hundreds of layers).
fn assert_same_spec(code: &str, parsed: &ModelSpec, exported: &ModelSpec) {
    assert_eq!(parsed.name, exported.name, "{code}: spec name");
    assert_eq!(parsed.layers.len(), exported.layers.len(), "{code}: layer count");
    for (a, b) in parsed.layers.iter().zip(&exported.layers) {
        assert_eq!(
            a, b,
            "{code}: bundled spec layer {:?} differs from the exporter — if the \
             builder changed intentionally, regenerate with UPDATE_SPECS=1",
            b.name
        );
    }
}

/// Builder-vs-spec structural identity for one benchmark code.
fn check_round_trip(code: &str) {
    let builder_net = benchmark_with_batch(code, paper_batch(code));
    let path = spec_dir().join(format!("{code}.json"));
    if std::env::var_os("UPDATE_SPECS").is_some() {
        fs::write(&path, export_json(&builder_net))
            .unwrap_or_else(|e| panic!("{code}: cannot rewrite {}: {e}", path.display()));
        eprintln!("spec {code}: regenerated {}", path.display());
        return;
    }
    let parsed = load_spec(&path).unwrap_or_else(|e| panic!("{code}: {e:#}"));
    assert_same_spec(code, &parsed, &export_network(&builder_net));

    let built = build_network(&parsed).unwrap_or_else(|e| panic!("{code}: {e:#}"));
    assert_same_network(code, &built, &builder_net);

    // Identical networks must lower to identical chains, in both modes.
    for mode in [Mode::Inference, Mode::Training] {
        let a = lower_network(&built, mode);
        let b = lower_network(&builder_net, mode);
        assert_eq!(a.len(), b.len(), "{code}: chain length ({mode:?})");
        assert_eq!(a.total_work(), b.total_work(), "{code}: chain work ({mode:?})");
        assert_eq!(format!("{a}"), format!("{b}"), "{code}: chain structure ({mode:?})");
    }
}

#[test]
fn bundled_specs_round_trip_all_seven_builders() {
    for code in BENCHMARK_CODES {
        check_round_trip(code);
    }
}

/// Run `net`'s inference chain on the fast tiers and return the final
/// output.
fn run_fast(net: &Network) -> Tensor {
    let (input_name, dims) = input_spec(net).unwrap();
    let mut exec = ChainExec::new(lower_network(net, Mode::Inference));
    exec.set_input(&input_name, Tensor::rand(&dims, SEED, 1.0));
    let mut report = exec.run_last().unwrap_or_else(|e| panic!("{}: {e:#}", net.name));
    (*report.outputs.remove(0)).clone()
}

fn assert_spec_executes_like_builder(code: &str) {
    let builder_net = benchmark_with_batch(code, 1);
    let spec = export_network(&builder_net);
    let built = build_network(&spec).unwrap_or_else(|e| panic!("{code}: {e:#}"));
    let want = run_fast(&builder_net);
    let got = run_fast(&built);
    assert!(
        want.bit_eq(&got),
        "{code}: spec-imported network diverged bitwise from the builder \
         (max |Δ| = {:e})",
        want.max_abs_diff(&got)
    );
}

#[test]
fn spec_networks_execute_bit_identically_mn_an() {
    for code in ["MN", "AN"] {
        assert_spec_executes_like_builder(code);
    }
}

#[test]
#[ignore = "full-size numerics over the heavy nets; CI runs this in release via \
            `cargo test --release -- --ignored`"]
fn spec_networks_execute_bit_identically_all_seven() {
    for code in BENCHMARK_CODES {
        assert_spec_executes_like_builder(code);
    }
}

#[test]
fn all_bundled_specs_import_and_lower() {
    let files = discover_specs();
    assert!(!files.is_empty(), "no bundled specs under {:?}", spec_dir());
    for path in files {
        let net = load_spec(&path)
            .and_then(|s| build_network(&s))
            .unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
        let chain = lower_network(&net, Mode::Inference);
        assert!(!chain.is_empty(), "{}: empty chain", path.display());
    }
}

#[test]
fn batch_override_matches_builder_at_that_batch() {
    let spec = load_spec(&spec_dir().join("MN.json")).unwrap();
    let built = build_with_batch(&spec, Some(4)).unwrap();
    assert_same_network("MN@4", &built, &benchmark_with_batch("MN", 4));
}

/// The custom spec-only CNN: every engine path bit-identical, fusion
/// actually shortens the chain, and the serving engine coalesces it to
/// the same bits as a direct session run.
#[test]
fn custom_spec_runs_identically_on_every_engine_path() {
    let spec = load_spec(&spec_dir().join("tinycnn.json")).unwrap();
    let net = build_network(&spec).unwrap();
    assert_eq!(net.name, "TinyCNN");
    let (input_name, dims) = input_spec(&net).unwrap();
    let x = Tensor::rand(&dims, SEED, 1.0);

    let run_exec = |fuse: bool, naive: bool| -> Tensor {
        let mut chain = lower_network(&net, Mode::Inference);
        if fuse {
            let stats = fuse_executable(&mut chain);
            assert!(stats.after < stats.before, "fusion must shorten the chain");
        }
        let mut exec = ChainExec::new(chain);
        if naive {
            exec = exec.with_naive_oracle();
        }
        exec.set_input(&input_name, x.clone());
        (*exec.run_last().unwrap().outputs.remove(0)).clone()
    };
    let reference = run_exec(false, true);
    for (fuse, naive) in [(false, false), (true, false)] {
        let out = run_exec(fuse, naive);
        assert!(
            reference.bit_eq(&out),
            "fuse={fuse}: diverged from the naive oracle (max |Δ| = {:e})",
            reference.max_abs_diff(&out)
        );
    }
    // Session path, second (reuse) run compared.
    let mut session = Session::builder(lower_network(&net, Mode::Inference))
        .input(&input_name, x.clone())
        .build()
        .unwrap();
    let first = session.run().unwrap();
    session.recycle(first);
    let second = session.run().unwrap();
    assert!(reference.bit_eq(&second.outputs[0]), "session reuse diverged");

    // Serving engine: single-sample requests, coalesced micro-batch.
    let mut engine = Engine::new(2);
    let code = engine.register_spec(spec).unwrap();
    let sample: Vec<f32> = x.data().to_vec();
    engine.submit(&code, 0, sample.clone()).unwrap();
    engine.submit(&code, 1, sample).unwrap();
    let mut responses = engine.drain().unwrap();
    responses.sort_by_key(|r| r.id);
    assert_eq!(responses.len(), 2);
    for r in &responses {
        assert_eq!(r.batch, 2, "TinyCNN is per-sample and must coalesce");
        let same = r
            .data
            .iter()
            .zip(reference.data())
            .all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(same, "engine-served output diverged from the direct runs");
    }
}

// ---------------------------------------------------------------------
// Inference-failure coverage: every malformed spec yields a targeted
// error naming the offending layer — no panics.
// ---------------------------------------------------------------------

fn parse_doc(layers: &str) -> Result<ModelSpec, anyhow::Error> {
    let doc = format!(
        "{{\"format\": \"gconv-chain-model\", \"version\": 1, \"name\": \"bad\", \
         \"layers\": [{layers}]}}"
    );
    ModelSpec::parse_json(&doc)
}

/// Parse + build, returning the full error chain as text.
fn build_err(layers: &str) -> String {
    let spec = match parse_doc(layers) {
        Ok(spec) => spec,
        Err(e) => return format!("{e:#}"),
    };
    match build_network(&spec) {
        Ok(_) => panic!("malformed spec built successfully: {layers}"),
        Err(e) => format!("{e:#}"),
    }
}

const DATA: &str =
    r#"{"name": "data", "kind": "input", "shape": [["B", 1], ["C", 4], ["H", 8], ["W", 8]]}"#;

#[test]
fn shape_mismatch_names_layer_and_dimension() {
    let err = build_err(&format!(
        "{DATA}, {}",
        r#"{"name": "c1", "kind": "conv", "kernel": 3, "pad": 1, "output": {"C": 8, "H": 9}}"#
    ));
    assert!(err.contains("\"c1\"") && err.contains("H = 9") && err.contains("H = 8"), "{err}");
}

#[test]
fn dangling_input_names_both_layers() {
    let err = build_err(&format!(
        "{DATA}, {}",
        r#"{"name": "r", "kind": "relu", "inputs": ["missing"]}"#
    ));
    assert!(err.contains("\"r\"") && err.contains("\"missing\""), "{err}");
}

#[test]
fn unknown_layer_kind_is_reported_with_known_kinds() {
    let err = build_err(&format!("{DATA}, {}", r#"{"name": "x", "kind": "swish"}"#));
    assert!(err.contains("\"x\"") && err.contains("unknown kind \"swish\""), "{err}");
    assert!(err.contains("conv"), "error should list known kinds: {err}");
}

#[test]
fn missing_required_field_is_reported() {
    let err = build_err(&format!("{DATA}, {}", r#"{"name": "c1", "kind": "conv"}"#));
    assert!(err.contains("\"c1\"") && err.contains("\"kernel\""), "{err}");
}

#[test]
fn oversized_kernel_is_reported_against_the_padded_input() {
    let err = build_err(&format!(
        "{DATA}, {}",
        r#"{"name": "c1", "kind": "conv", "out_channels": 8, "kernel": 11}"#
    ));
    assert!(err.contains("\"c1\"") && err.contains("kernel 11"), "{err}");
}

#[test]
fn group_divisibility_is_reported() {
    let err = build_err(&format!(
        "{DATA}, {}",
        r#"{"name": "c1", "kind": "conv", "out_channels": 8, "kernel": 3, "pad": 1, "groups": 3}"#
    ));
    assert!(err.contains("\"c1\"") && err.contains("groups 3"), "{err}");
}

#[test]
fn concat_shape_disagreement_is_reported() {
    let err = build_err(&format!(
        "{DATA}, {}, {}, {}",
        r#"{"name": "a", "kind": "pool", "kernel": 2, "inputs": ["data"]}"#,
        r#"{"name": "b", "kind": "relu", "inputs": ["data"]}"#,
        r#"{"name": "cat", "kind": "concat", "inputs": ["a", "b"]}"#
    ));
    assert!(err.contains("\"cat\"") && err.contains("disagrees on H"), "{err}");
}

#[test]
fn eltwise_shape_disagreement_is_reported() {
    let err = build_err(&format!(
        "{DATA}, {}, {}",
        r#"{"name": "c1", "kind": "conv", "out_channels": 8, "kernel": 3, "pad": 1}"#,
        r#"{"name": "j", "kind": "eltwise", "inputs": ["data", "c1"]}"#
    ));
    assert!(err.contains("\"j\"") && err.contains("eltwise"), "{err}");
}

#[test]
fn spec_without_input_layer_is_rejected() {
    let err = build_err(r#"{"name": "r", "kind": "relu", "inputs": ["r"]}"#);
    assert!(err.contains("\"r\""), "{err}");
}

#[test]
fn unknown_attribute_is_rejected() {
    let err = build_err(&format!(
        "{DATA}, {}",
        r#"{"name": "c1", "kind": "conv", "out_channels": 8, "kernel": 3, "striide": 2}"#
    ));
    assert!(err.contains("\"c1\"") && err.contains("\"striide\""), "{err}");
}

#[test]
fn wrong_version_is_rejected() {
    let doc = r#"{"format": "gconv-chain-model", "version": 9, "name": "x", "layers": []}"#;
    let err = ModelSpec::parse_json(doc).unwrap_err().to_string();
    assert!(err.contains("version 9"), "{err}");
}

#[test]
fn resolve_finds_bundled_specs_by_stem_and_path() {
    // `tinycnn` is not a benchmark code; it resolves via the spec dir.
    let net = gconv_chain::networks::resolve("tinycnn").unwrap();
    assert_eq!(net.name, "TinyCNN");
    let path = spec_dir().join("tinycnn.json");
    let net = gconv_chain::networks::resolve(path.to_str().unwrap()).unwrap();
    assert_eq!(net.name, "TinyCNN");
    // And typos list what would have worked.
    let err = gconv_chain::networks::resolve("tinycn").unwrap_err().to_string();
    assert!(err.contains("tinycnn"), "{err}");
}

/// Shape inference alone is not the safety gate: a spec that imports
/// and infers cleanly can still fail the static chain audit (forced
/// here via the resource-budget rule), and the diagnostic names the
/// chain entry — i.e. the layer — that violated it.
#[test]
fn audit_rejects_an_inference_clean_spec_under_budget() {
    use gconv_chain::analysis::{audit_chain_with, AuditConfig, Rule};

    let spec = load_spec(&spec_dir().join("tinycnn.json")).unwrap();
    let net = build_network(&spec).unwrap(); // shape inference passes
    let chain = lower_network(&net, Mode::Inference);
    let cfg = AuditConfig { budget_bytes: 16, ..Default::default() };
    let rep = audit_chain_with(&chain, &cfg);
    assert!(rep.has(Rule::ResourcePeak), "{rep}");
    let diag = rep.diagnostics().iter().find(|d| d.rule == Rule::ResourcePeak).unwrap();
    assert!(diag.entry.is_some(), "{diag}");
    assert!(!diag.name.is_empty(), "diagnostic should name the layer: {diag}");
    assert!(diag.to_string().contains("resource.peak"), "{diag}");
}

/// The `specs` CLI gate audits every bundled spec and exits non-zero
/// with the violated rule on stderr when one fails (spec dir pinned to
/// a one-spec copy so the failure is attributable; budget forced down
/// via the `GCONV_AUDIT_BUDGET` env lever).
#[test]
fn specs_subcommand_fails_on_audit_diagnostics() {
    let dir = std::env::temp_dir().join(format!("gconv_audit_specs_{}", std::process::id()));
    fs::create_dir_all(&dir).unwrap();
    fs::copy(spec_dir().join("tinycnn.json"), dir.join("tinycnn.json")).unwrap();

    let out = std::process::Command::new(env!("CARGO_BIN_EXE_gconv-chain"))
        .arg("specs")
        .env("GCONV_SPEC_DIR", &dir)
        .env("GCONV_AUDIT_BUDGET", "16")
        .output()
        .unwrap();
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(!out.status.success(), "specs should exit non-zero; stderr:\n{stderr}");
    assert!(stderr.contains("resource.peak"), "stderr should name the rule:\n{stderr}");

    // With no budget pressure the same spec dir passes the gate.
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_gconv-chain"))
        .arg("specs")
        .env("GCONV_SPEC_DIR", &dir)
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr:\n{}", String::from_utf8_lossy(&out.stderr));
    fs::remove_dir_all(&dir).ok();
}
