//! Integration: the full python-AOT → rust-PJRT path with real numerics.
//!
//! Requires `make artifacts` (the Makefile `test` target guarantees it).
//! Validates that the HLO-text artifacts — which embed the L1 Pallas
//! GCONV kernels and the L2 chain graphs — compile on the rust PJRT CPU
//! client and compute the same numbers as simple rust-side references.

use gconv_chain::runtime::{literal_f32, to_vec_f32, Runtime};

fn artifacts_ready() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

/// Deterministic pseudo-random data (must not depend on rand crates).
fn data(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = gconv_chain::prop::Rng::new(seed);
    (0..n).map(|_| rng.f64() as f32 * 2.0 - 1.0).collect()
}

#[test]
fn gconv_generic_matches_rust_reference() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let (b, c, o, hw, k) = (4usize, 8usize, 16usize, 12usize, 3usize);
    let x = data(b * c * hw * hw, 1);
    let w = data(o * c * k * k, 2);
    let mut rt = Runtime::cpu("artifacts").unwrap();
    let out = rt
        .execute(
            "gconv_generic",
            &[
                literal_f32(&x, &[b as i64, c as i64, hw as i64, hw as i64]).unwrap(),
                literal_f32(&w, &[o as i64, c as i64, k as i64, k as i64]).unwrap(),
            ],
        )
        .unwrap();
    let got = to_vec_f32(&out[0]).unwrap();
    assert_eq!(got.len(), b * o * hw * hw);

    // Rust-side reference: plain padded conv.
    let pad = 1i64;
    let idx = |bi: usize, ci: usize, y: i64, xx: i64| -> f32 {
        if y < 0 || xx < 0 || y >= hw as i64 || xx >= hw as i64 {
            0.0
        } else {
            x[((bi * c + ci) * hw + y as usize) * hw + xx as usize]
        }
    };
    let mut max_err = 0f32;
    // Spot-check a grid of output positions (full check is O(1e7) — fine
    // but slow in debug builds).
    for bi in 0..b {
        for oi in (0..o).step_by(5) {
            for y in (0..hw).step_by(3) {
                for xx in (0..hw).step_by(3) {
                    let mut acc = 0f32;
                    for ci in 0..c {
                        for ky in 0..k {
                            for kx in 0..k {
                                let w_v = w[((oi * c + ci) * k + ky) * k + kx];
                                acc += w_v
                                    * idx(bi, ci, y as i64 + ky as i64 - pad, xx as i64 + kx as i64 - pad);
                            }
                        }
                    }
                    let got_v = got[((bi * o + oi) * hw + y) * hw + xx];
                    max_err = max_err.max((got_v - acc).abs());
                }
            }
        }
    }
    assert!(max_err < 1e-3, "max abs err {max_err}");
}

#[test]
fn bn_train_normalizes_and_backprops() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let (b, c, hw) = (8usize, 32usize, 8usize);
    let n = b * c * hw * hw;
    let x = data(n, 3);
    let g = data(n, 4);
    let mut rt = Runtime::cpu("artifacts").unwrap();
    let dims = [b as i64, c as i64, hw as i64, hw as i64];
    let out = rt
        .execute("bn_train", &[literal_f32(&x, &dims).unwrap(), literal_f32(&g, &dims).unwrap()])
        .unwrap();
    assert_eq!(out.len(), 2);
    let o = to_vec_f32(&out[0]).unwrap();
    let gi = to_vec_f32(&out[1]).unwrap();

    // Forward: per-feature batch statistics must be normalized.
    let feat = c * hw * hw;
    for f in (0..feat).step_by(97) {
        let mut mean = 0f64;
        let mut var = 0f64;
        for bi in 0..b {
            mean += o[bi * feat + f] as f64;
        }
        mean /= b as f64;
        for bi in 0..b {
            var += (o[bi * feat + f] as f64 - mean).powi(2);
        }
        var /= b as f64;
        assert!(mean.abs() < 1e-4, "feature {f} mean {mean}");
        assert!((var - 1.0).abs() < 1e-2, "feature {f} var {var}");
    }

    // Backward invariant of BN: the gradient projects out the mean and
    // the O-direction — per feature, Σ_b gI = 0 and Σ_b gI·O = 0.
    for f in (0..feat).step_by(113) {
        let mut s = 0f64;
        let mut so = 0f64;
        for bi in 0..b {
            s += gi[bi * feat + f] as f64;
            so += gi[bi * feat + f] as f64 * o[bi * feat + f] as f64;
        }
        assert!(s.abs() < 1e-3, "feature {f}: sum gI = {s}");
        assert!(so.abs() < 1e-3, "feature {f}: <gI, O> = {so}");
    }
}

#[test]
fn mobilenet_block_runs_and_is_rectified() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let (b, c, hw) = (8usize, 16usize, 14usize);
    let x = data(b * c * hw * hw, 5);
    let dw = data(c * 9, 6);
    let pw = data(2 * c * c, 7);
    let mut rt = Runtime::cpu("artifacts").unwrap();
    let out = rt
        .execute(
            "mobilenet_block",
            &[
                literal_f32(&x, &[b as i64, c as i64, hw as i64, hw as i64]).unwrap(),
                literal_f32(&dw, &[c as i64, 1, 3, 3]).unwrap(),
                literal_f32(&pw, &[2 * c as i64, c as i64, 1, 1]).unwrap(),
            ],
        )
        .unwrap();
    let y = to_vec_f32(&out[0]).unwrap();
    assert_eq!(y.len(), b * 2 * c * hw * hw);
    // Final ReLU: non-negative, and (with random inputs + BN) roughly
    // half the activations are exactly zero.
    assert!(y.iter().all(|&v| v >= 0.0));
    let zeros = y.iter().filter(|&&v| v == 0.0).count() as f64 / y.len() as f64;
    assert!((0.2..0.8).contains(&zeros), "zero fraction {zeros}");
}

#[test]
fn executables_are_cached() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let mut rt = Runtime::cpu("artifacts").unwrap();
    rt.load("gconv_generic").unwrap();
    rt.load("gconv_generic").unwrap();
    assert_eq!(rt.cached(), 1);
}
