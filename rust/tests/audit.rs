//! Mutation tests for the static chain auditor: corrupt a valid
//! lowered chain in targeted ways and assert the audit rejects it with
//! the *named* rule id — plus clean-audit coverage over the benchmark
//! networks (MN + AN in tier-1, all seven + the bundled `tinycnn` spec
//! in the release `--ignored` run).
//!
//! Corruptions go through `GconvChain::entries_mut` deliberately:
//! `push` asserts backward references at build time, and the point of
//! these tests is a chain that *bypassed* construction-time checks.

use gconv_chain::analysis::{audit_chain, audit_chain_with, AuditConfig, Rule};
use gconv_chain::gconv::chain::{FusedOp, GconvChain, SpecialOp};
use gconv_chain::gconv::lower::{lower_network, Mode};
use gconv_chain::gconv::op::{DataRef, MainOp, PostOp, PreOp};
use gconv_chain::mapping::fuse_executable;
use gconv_chain::networks::{mobilenet_block, resolve, resolve_with_batch, BENCHMARK_CODES};
use gconv_chain::prop::{prop_check, Rng};

/// The clean baseline every corruption starts from.
fn block_chain(fuse: bool) -> GconvChain {
    let mut chain = lower_network(&mobilenet_block(2, 8, 16), Mode::Inference);
    if fuse {
        fuse_executable(&mut chain);
    }
    chain
}

fn pick_site(rng: &mut Rng, sites: &[usize]) -> Option<usize> {
    if sites.is_empty() {
        None
    } else {
        Some(sites[rng.int(0, sites.len() - 1)])
    }
}

/// One corruption class: mutate the chain, return the rule that must
/// flag it (`None` when the chain offers no applicable site).
type Corrupt = fn(&mut GconvChain, &mut Rng) -> Option<Rule>;

/// Class 1 — a zero loop parameter (stride 0 divides the audit's own
/// derivations, so everything downstream keys off this rule).
fn corrupt_zero_stride(chain: &mut GconvChain, rng: &mut Rng) -> Option<Rule> {
    let sites: Vec<usize> = chain
        .entries()
        .iter()
        .enumerate()
        .filter(|(_, e)| e.special.is_none() && !e.op.dims.is_empty())
        .map(|(i, _)| i)
        .collect();
    let i = pick_site(rng, &sites)?;
    chain.entries_mut()[i].op.dims[0].1.s = 0;
    Some(Rule::CoverageParams)
}

/// Class 2 — a reduction window inflated past everything its producer
/// emits: the loop nest would read out of bounds.
fn corrupt_window_overrun(chain: &mut GconvChain, rng: &mut Rng) -> Option<Rule> {
    let mut sites: Vec<(usize, usize)> = Vec::new();
    let entries = chain.entries();
    for (i, e) in entries.iter().enumerate() {
        if e.special.is_some() || e.op.dims.is_empty() {
            continue;
        }
        let DataRef::Gconv(p) = e.op.input else {
            continue;
        };
        if p >= i {
            continue;
        }
        let prod = entries[p].op.output_extents();
        // A rank-aligned extent-1 first dimension is a legal broadcast
        // no window size can violate — not a corruption site.
        if prod.len() == e.op.dims.len() && prod.first().copied().unwrap_or(1) == 1 {
            continue;
        }
        let elements: usize = prod.iter().product();
        sites.push((i, elements.max(1)));
    }
    if sites.is_empty() {
        return None;
    }
    let (i, elements) = sites[rng.int(0, sites.len() - 1)];
    chain.entries_mut()[i].op.dims[0].1.nks += elements + 7;
    Some(Rule::CoverageInput)
}

/// Class 3 — a self/forward operand reference (the executor's level
/// scheduler would deadlock or read uninitialized data).
fn corrupt_forward_reference(chain: &mut GconvChain, rng: &mut Rng) -> Option<Rule> {
    if chain.is_empty() {
        return None;
    }
    let i = rng.int(0, chain.len() - 1);
    chain.entries_mut()[i].op.input = DataRef::Gconv(i);
    Some(Rule::DataflowAcyclic)
}

/// Class 4 — a scalar-pipeline LUT name the interpreter cannot
/// resolve (a guaranteed bind error at run time).
fn corrupt_unknown_lut(chain: &mut GconvChain, rng: &mut Rng) -> Option<Rule> {
    if chain.is_empty() {
        return None;
    }
    let i = rng.int(0, chain.len() - 1);
    chain.entries_mut()[i].op.post = PostOp::Lut("definitely_not_a_lut");
    Some(Rule::DataflowLut)
}

/// Class 5 — a padded host carrying a fused `pre` that maps the
/// padding value +0.0 to 0.5 (sigmoid): the silent-corruption case the
/// fusion pass must refuse.
fn corrupt_poisoned_fused_pre(chain: &mut GconvChain, rng: &mut Rng) -> Option<Rule> {
    let sites: Vec<usize> = chain
        .entries()
        .iter()
        .enumerate()
        .filter(|(_, e)| {
            e.special.is_none() && e.op.dims.iter().any(|&(_, p)| p.ps > 0 || p.pe > 0)
        })
        .map(|(i, _)| i)
        .collect();
    let i = pick_site(rng, &sites)?;
    let e = &mut chain.entries_mut()[i];
    e.op.pre = PreOp::Lut("sigmoid");
    e.fused.push(FusedOp { name: "poison".into(), slot: "pre", param_elements: 0 });
    Some(Rule::FusionPadding)
}

/// Class 6 — a fusion provenance record naming an operator slot that
/// does not exist.
fn corrupt_alien_slot(chain: &mut GconvChain, rng: &mut Rng) -> Option<Rule> {
    if chain.is_empty() {
        return None;
    }
    let i = rng.int(0, chain.len() - 1);
    chain.entries_mut()[i]
        .fused
        .push(FusedOp { name: "alien".into(), slot: "sideways", param_elements: 0 });
    Some(Rule::FusionSlot)
}

/// Class 7 — a parameter-consuming main operator with its kernel
/// operand stripped.
fn corrupt_missing_kernel(chain: &mut GconvChain, rng: &mut Rng) -> Option<Rule> {
    let sites: Vec<usize> = chain
        .entries()
        .iter()
        .enumerate()
        .filter(|(_, e)| e.special.is_none() && !matches!(e.op.main, MainOp::Pass))
        .map(|(i, _)| i)
        .collect();
    let i = pick_site(rng, &sites)?;
    chain.entries_mut()[i].op.kernel = None;
    Some(Rule::CoverageKernel)
}

const CLASSES: &[(&str, Corrupt)] = &[
    ("zero-stride", corrupt_zero_stride),
    ("window-overrun", corrupt_window_overrun),
    ("forward-reference", corrupt_forward_reference),
    ("unknown-lut", corrupt_unknown_lut),
    ("poisoned-fused-pre", corrupt_poisoned_fused_pre),
    ("alien-fusion-slot", corrupt_alien_slot),
    ("missing-kernel", corrupt_missing_kernel),
];

/// Every corruption class, applied to a random site of a random
/// (fused or unfused) clean chain, must be rejected with its rule id —
/// and each class must actually fire during the run.
#[test]
fn mutated_chains_are_rejected_with_the_named_rule() {
    let mut fired = vec![false; CLASSES.len()];
    prop_check(64, |rng| {
        let k = rng.int(0, CLASSES.len() - 1);
        let (label, apply) = CLASSES[k];
        let mut chain = block_chain(rng.bool(0.5));
        let Some(rule) = apply(&mut chain, rng) else {
            return Ok(()); // no applicable site in this variant
        };
        fired[k] = true;
        let rep = audit_chain(&chain);
        if !rep.has(rule) {
            return Err(format!("{label}: expected rule {} to fire; report:\n{rep}", rule.id()));
        }
        Ok(())
    });
    for (hit, (label, _)) in fired.iter().zip(CLASSES) {
        assert!(*hit, "corruption class {label} never found an applicable site");
    }
}

/// Each class rejected deterministically too (one fixed seed), so a
/// single failing class names itself without replaying the property.
#[test]
fn each_corruption_class_is_rejected() {
    for (label, apply) in CLASSES {
        let mut rng = Rng::new(7);
        let mut chain = block_chain(false);
        let rule = apply(&mut chain, &mut rng)
            .unwrap_or_else(|| panic!("{label}: no applicable site in the unfused block chain"));
        let rep = audit_chain(&chain);
        assert!(rep.has(rule), "{label}: expected {}; report:\n{rep}", rule.id());
    }
}

/// A max-pool BP scatter whose forward geometry multiplexes groups
/// would route gradients across window sets — the write-disjointness
/// rule for `exec::special`'s scatter site.
#[test]
fn scatter_group_corruption_flags_disjoint_scatter() {
    let net = resolve_with_batch("AN", Some(1)).unwrap();
    let mut chain = lower_network(&net, Mode::Training);
    let site = chain.entries_mut().iter_mut().find_map(|e| {
        if let Some(SpecialOp::MaxPoolBp { fwd, .. }) = &mut e.special {
            fwd[0].1.ng = 2;
            return Some(e.op.name.clone());
        }
        None
    });
    assert!(site.is_some(), "AN training chain should hold a max-pool BP entry");
    let rep = audit_chain(&chain);
    assert!(rep.has(Rule::DisjointScatter), "{rep}");
}

/// A concat step whose axis points past the output rank cannot tile
/// the output — the disjointness rule for the concat copy site.
#[test]
fn concat_axis_corruption_flags_disjoint_concat() {
    let net = resolve_with_batch("GLN", Some(1)).unwrap();
    let mut chain = lower_network(&net, Mode::Inference);
    let mut hit = false;
    for e in chain.entries_mut().iter_mut() {
        if let Some(SpecialOp::Concat { axis, .. }) = &mut e.special {
            *axis = 99;
            hit = true;
            break;
        }
    }
    assert!(hit, "GLN inference chain should hold a concat entry");
    let rep = audit_chain(&chain);
    assert!(rep.has(Rule::DisjointConcat), "{rep}");
}

/// The resource pass reports the peak and flags it against a budget.
#[test]
fn tiny_budget_flags_resource_peak() {
    let chain = block_chain(false);
    let cfg = AuditConfig { budget_bytes: 1, ..Default::default() };
    let rep = audit_chain_with(&chain, &cfg);
    assert!(rep.has(Rule::ResourcePeak), "{rep}");
    assert!(rep.peak_live_bytes > 1);
    // The same chain under no budget is clean and reports the same peak.
    let clean = audit_chain(&chain);
    assert!(clean.is_clean(), "{clean}");
    assert_eq!(clean.peak_live_bytes, rep.peak_live_bytes);
}

/// A wanted output past the end of the chain is a schedule finding,
/// not a panic.
#[test]
fn out_of_range_wanted_flags_schedule() {
    let chain = block_chain(false);
    let cfg = AuditConfig { wanted: Some(vec![chain.len() + 5]), ..Default::default() };
    let rep = audit_chain_with(&chain, &cfg);
    assert!(rep.has(Rule::DataflowSchedule), "{rep}");
}

fn assert_network_clean(code: &str, batch: Option<usize>) {
    let net = resolve_with_batch(code, batch).expect("benchmark network resolves");
    for mode in [Mode::Inference, Mode::Training] {
        for fuse in [false, true] {
            let mut chain = lower_network(&net, mode);
            if fuse {
                fuse_executable(&mut chain);
            }
            let rep = audit_chain(&chain);
            assert!(rep.is_clean(), "{code} {mode:?} fuse={fuse}:\n{rep}");
            assert!(rep.total_checked() > 0, "{code}: no obligations discharged");
        }
    }
}

/// Tier-1 clean-audit coverage: MN + AN, both modes, fused + unfused.
#[test]
fn mn_and_an_audit_clean() {
    assert_network_clean("MN", Some(1));
    assert_network_clean("AN", Some(1));
}

/// Release tier: every benchmark network plus the spec-only custom CNN
/// audits clean in every mode/fusion combination.
#[test]
#[ignore = "release tier: lowers all seven full networks"]
fn all_benchmarks_and_tinycnn_audit_clean() {
    for code in BENCHMARK_CODES {
        assert_network_clean(code, None);
    }
    let net = resolve("tinycnn").unwrap();
    for fuse in [false, true] {
        let mut chain = lower_network(&net, Mode::Inference);
        if fuse {
            fuse_executable(&mut chain);
        }
        let rep = audit_chain(&chain);
        assert!(rep.is_clean(), "tinycnn fuse={fuse}:\n{rep}");
    }
}
